"""Flash attention with a memory-bounded custom-VJP backward.

Plain reverse-mode AD through a chunked online-softmax scan stacks every
block's probability matrix as a residual — O(Sq*Skv) memory, exactly what
flash attention exists to avoid.  This module implements the FA-2 backward:
the forward saves only (q, k, v, out, lse); the backward recomputes each
(q-chunk, kv-chunk) probability block from those and accumulates dq/dk/dv.
Peak memory is O(block^2) per head regardless of sequence length.

Mask semantics are encoded as traced int32 scalars so per-layer flags
(e.g. Gemma3's scanned local/global pattern) stay scan-compatible:
  window  : sliding-window size (WINDOW_INF = unbounded)
  q_offset: absolute position of q[0] (decode)
  kv_len  : number of valid kv positions (padding / partial cache)
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

Array = jax.Array
NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)
WINDOW_INF = jnp.int32(2 ** 30)


def _block_ok(q_pos, k_pos, causal: bool, window, q_offset, kv_len):
    """(qc, kc) bool allowed-mask for one block."""
    q_abs = q_pos + q_offset
    ok = (k_pos < kv_len)[None, :]
    if causal:
        ok = ok & (k_pos[None, :] <= q_abs[:, None])
        ok = ok & (k_pos[None, :] > q_abs[:, None] - window)
    return ok


def _fwd_impl(qc: int, kc: int, causal: bool, q, k, v,
              window, q_offset, kv_len):
    """Returns (out (B,Sq,H,hd), lse (B,KV,g,Sq))."""
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    g = H // KV
    scale = 1.0 / math.sqrt(hd)

    qs = q.astype(jnp.float32) * scale
    nq, nk = -(-Sq // qc), -(-Skv // kc)
    qp = jnp.pad(qs, ((0, 0), (0, nq * qc - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * kc - Skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * kc - Skv), (0, 0), (0, 0)))
    qv = qp.reshape(B, nq, qc, KV, g, hd)
    kv_ = kp.reshape(B, nk, kc, KV, hd)
    vv = vp.reshape(B, nk, kc, KV, hd)
    kv_len_eff = jnp.minimum(jnp.asarray(kv_len, jnp.int32), Skv)

    def q_block(i, q_i):
        q_pos = i * qc + jnp.arange(qc)

        def kv_step(carry, j):
            acc, m_run, d_run = carry
            k_j = kv_[:, j].astype(jnp.float32)
            v_j = vv[:, j].astype(jnp.float32)
            s = jnp.einsum("bqkgh,bckh->bkgqc", q_i, k_j)
            k_pos = j * kc + jnp.arange(kc)
            ok = _block_ok(q_pos, k_pos, causal, window, q_offset, kv_len_eff)
            s = jnp.where(ok[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            d_new = d_run * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqc,bckh->bkgqh", p, v_j)
            acc = acc * corr[..., None] + pv
            return (acc, m_new, d_new), None

        acc0 = jnp.zeros((B, KV, g, qc, hd), jnp.float32)
        m0 = jnp.full((B, KV, g, qc), NEG_INF, jnp.float32)
        d0 = jnp.zeros((B, KV, g, qc), jnp.float32)
        (acc, m_run, d_run), _ = jax.lax.scan(
            kv_step, (acc0, m0, d0), jnp.arange(nk))
        out_i = acc / jnp.maximum(d_run[..., None], 1e-37)
        lse_i = m_run + jnp.log(jnp.maximum(d_run, 1e-37))
        return out_i, lse_i

    if nq == 1:
        out, lse = q_block(0, qv[:, 0])
        out, lse = out[:, :, :, None], lse[:, :, :, None]
        out = jnp.moveaxis(out, 3, 1)         # (B,1,KV,g,qc,hd)
        lse = jnp.moveaxis(lse, 3, 1)
    else:
        out, lse = jax.lax.map(lambda a: q_block(*a),
                               (jnp.arange(nq), jnp.moveaxis(qv, 1, 0)))
        out = jnp.moveaxis(out, 0, 1)          # (B,nq,KV,g,qc,hd)
        lse = jnp.moveaxis(lse, 0, 1)          # (B,nq,KV,g,qc)

    out = out.reshape(B, nq, KV, g, qc, hd)
    out = jnp.moveaxis(out, (2, 3), (3, 4)).reshape(B, nq * qc, KV * g, hd)
    lse = lse.reshape(B, nq, KV, g, qc)
    lse = jnp.moveaxis(lse, 1, 3).reshape(B, KV, g, nq * qc)
    return out[:, :Sq].astype(k.dtype), lse[..., :Sq]


def _tri_pairs(nq: int, qc: int, kc: int):
    """Static lower-triangular (q-chunk, kv-chunk) pair list."""
    import numpy as _np
    pairs = [(i, j) for i in range(nq)
             for j in range(((i + 1) * qc + kc - 1) // kc)]
    i_idx = _np.asarray([p[0] for p in pairs], _np.int32)
    j_idx = _np.asarray([p[1] for p in pairs], _np.int32)
    return i_idx, j_idx


def _fwd_tri(qc: int, kc: int, q, k, v, window, q_offset, kv_len):
    """Causal block-skipping forward: iterate only the ~nq^2/2 chunk pairs
    below the causal diagonal (one flat scan; online softmax state lives in
    the carry, indexed per q-chunk).  ~2x fewer attention FLOPs than the
    dense chunk grid for causal masks."""
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    g = H // KV
    scale = 1.0 / math.sqrt(hd)
    f32 = jnp.float32

    qs = q.astype(f32) * scale
    nq, nk = -(-Sq // qc), -(-Skv // kc)
    qp = jnp.pad(qs, ((0, 0), (0, nq * qc - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * kc - Skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * kc - Skv), (0, 0), (0, 0)))
    qv = qp.reshape(B, nq, qc, KV, g, hd)
    kv_ = kp.reshape(B, nk, kc, KV, hd)
    vv = vp.reshape(B, nk, kc, KV, hd)
    kv_len_eff = jnp.minimum(jnp.asarray(kv_len, jnp.int32), Skv)
    i_idx, j_idx = _tri_pairs(nq, qc, kc)

    def step(carry, ij):
        acc, m_run, d_run = carry           # (B,KV,g,nq,qc,[hd])
        i, j = ij
        q_i = jax.lax.dynamic_index_in_dim(qv, i, 1, keepdims=False)
        k_j = jax.lax.dynamic_index_in_dim(kv_, j, 1, keepdims=False).astype(f32)
        v_j = jax.lax.dynamic_index_in_dim(vv, j, 1, keepdims=False).astype(f32)
        s = jnp.einsum("bqkgh,bckh->bkgqc", q_i, k_j)
        q_pos = i * qc + jnp.arange(qc)
        k_pos = j * kc + jnp.arange(kc)
        ok = _block_ok(q_pos, k_pos, True, window, q_offset, kv_len_eff)
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        m_i = jax.lax.dynamic_index_in_dim(m_run, i, 3, keepdims=False)
        d_i = jax.lax.dynamic_index_in_dim(d_run, i, 3, keepdims=False)
        a_i = jax.lax.dynamic_index_in_dim(acc, i, 3, keepdims=False)
        m_new = jnp.maximum(m_i, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_i - m_new)
        d_new = d_i * corr + p.sum(axis=-1)
        a_new = a_i * corr[..., None] + jnp.einsum("bkgqc,bckh->bkgqh", p, v_j)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, i, 3)
        m_run = jax.lax.dynamic_update_index_in_dim(m_run, m_new, i, 3)
        d_run = jax.lax.dynamic_update_index_in_dim(d_run, d_new, i, 3)
        return (acc, m_run, d_run), None

    acc0 = jnp.zeros((B, KV, g, nq, qc, hd), f32)
    m0 = jnp.full((B, KV, g, nq, qc), NEG_INF, f32)
    d0 = jnp.zeros((B, KV, g, nq, qc), f32)
    (acc, m_run, d_run), _ = jax.lax.scan(
        step, (acc0, m0, d0), (jnp.asarray(i_idx), jnp.asarray(j_idx)))
    out = acc / jnp.maximum(d_run[..., None], 1e-37)     # (B,KV,g,nq,qc,hd)
    lse = m_run + jnp.log(jnp.maximum(d_run, 1e-37))
    out = jnp.moveaxis(out, (1, 2), (3, 4)).reshape(B, nq * qc, KV * g, hd)
    lse = lse.reshape(B, KV, g, nq * qc)
    return out[:, :Sq].astype(k.dtype), lse[..., :Sq]


def _bwd_tri(qc: int, kc: int, res, dout):
    """Block-skipping backward over the same triangular pair set."""
    q, k, v, out, lse, window, q_offset, kv_len = res
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    g = H // KV
    scale = 1.0 / math.sqrt(hd)
    f32 = jnp.float32
    nq, nk = -(-Sq // qc), -(-Skv // kc)
    pad_q, pad_k = nq * qc - Sq, nk * kc - Skv

    def qb(x):
        xp = jnp.pad(x.astype(f32), ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        return xp.reshape(B, nq, qc, KV, g, hd)

    qv = qb(q) * scale
    dob = qb(dout)
    ob = qb(out)
    kb = jnp.pad(k.astype(f32), ((0, 0), (0, pad_k), (0, 0), (0, 0))
                 ).reshape(B, nk, kc, KV, hd)
    vb = jnp.pad(v.astype(f32), ((0, 0), (0, pad_k), (0, 0), (0, 0))
                 ).reshape(B, nk, kc, KV, hd)
    lseb = jnp.pad(lse, ((0, 0), (0, 0), (0, 0), (0, pad_q))
                   ).reshape(B, KV, g, nq, qc)
    delta = jnp.einsum("bnqkgh,bnqkgh->bkgnq", dob, ob)
    kv_len_eff = jnp.minimum(jnp.asarray(kv_len, jnp.int32), Skv)
    i_idx, j_idx = _tri_pairs(nq, qc, kc)

    def step(carry, ij):
        dq_acc, dk_acc, dv_acc = carry
        i, j = ij
        q_i = jax.lax.dynamic_index_in_dim(qv, i, 1, keepdims=False)
        do_i = jax.lax.dynamic_index_in_dim(dob, i, 1, keepdims=False)
        k_j = jax.lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)
        v_j = jax.lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
        s = jnp.einsum("bqkgh,bckh->bkgqc", q_i, k_j)
        q_pos = i * qc + jnp.arange(qc)
        k_pos = j * kc + jnp.arange(kc)
        ok = _block_ok(q_pos, k_pos, True, window, q_offset, kv_len_eff)
        ok = ok & (q_pos < Sq)[:, None]
        lse_i = jax.lax.dynamic_index_in_dim(lseb, i, 3, keepdims=False)
        p = jnp.where(ok[None, None, None], jnp.exp(s - lse_i[..., None]), 0.0)
        dv_ij = jnp.einsum("bkgqc,bqkgh->bckh", p, do_i)
        dp = jnp.einsum("bqkgh,bckh->bkgqc", do_i, v_j)
        delta_i = jax.lax.dynamic_index_in_dim(delta, i, 3, keepdims=False)
        ds = p * (dp - delta_i[..., None])
        dq_ij = jnp.einsum("bkgqc,bckh->bqkgh", ds, k_j)
        dk_ij = jnp.einsum("bkgqc,bqkgh->bckh", ds, q_i)
        dq_i = jax.lax.dynamic_index_in_dim(dq_acc, i, 1, keepdims=False)
        dq_acc = jax.lax.dynamic_update_index_in_dim(dq_acc, dq_i + dq_ij, i, 1)
        dk_j = jax.lax.dynamic_index_in_dim(dk_acc, j, 1, keepdims=False)
        dk_acc = jax.lax.dynamic_update_index_in_dim(dk_acc, dk_j + dk_ij, j, 1)
        dv_j = jax.lax.dynamic_index_in_dim(dv_acc, j, 1, keepdims=False)
        dv_acc = jax.lax.dynamic_update_index_in_dim(dv_acc, dv_j + dv_ij, j, 1)
        return (dq_acc, dk_acc, dv_acc), None

    dq0 = jnp.zeros((B, nq, qc, KV, g, hd), f32)
    dk0 = jnp.zeros((B, nk, kc, KV, hd), f32)
    dv0 = jnp.zeros((B, nk, kc, KV, hd), f32)
    (dq, dk, dv), _ = jax.lax.scan(
        step, (dq0, dk0, dv0), (jnp.asarray(i_idx), jnp.asarray(j_idx)))
    dq = (dq * scale).reshape(B, nq * qc, H, hd)[:, :Sq].astype(q.dtype)
    dk = dk.reshape(B, nk * kc, KV, hd)[:, :Skv].astype(k.dtype)
    dv = dv.reshape(B, nk * kc, KV, hd)[:, :Skv].astype(v.dtype)
    return dq, dk, dv, None, None, None


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _flash(qc: int, kc: int, causal: bool, block_skip: bool,
           q, k, v, window, q_offset, kv_len):
    if block_skip and causal:
        out, _ = _fwd_tri(qc, kc, q, k, v, window, q_offset, kv_len)
        return out
    out, _ = _fwd_impl(qc, kc, causal, q, k, v, window, q_offset, kv_len)
    return out


def _flash_fwd(qc, kc, causal, block_skip, q, k, v, window, q_offset, kv_len):
    if block_skip and causal:
        out, lse = _fwd_tri(qc, kc, q, k, v, window, q_offset, kv_len)
    else:
        out, lse = _fwd_impl(qc, kc, causal, q, k, v, window, q_offset, kv_len)
    return out, (q, k, v, out, lse, window, q_offset, kv_len)


def _flash_bwd(qc, kc, causal, block_skip, res, dout):
    if block_skip and causal:
        return _bwd_tri(qc, kc, res, dout)
    return _flash_bwd_dense(qc, kc, causal, res, dout)


def _flash_bwd_dense(qc, kc, causal, res, dout):
    q, k, v, out, lse, window, q_offset, kv_len = res
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    g = H // KV
    scale = 1.0 / math.sqrt(hd)
    f32 = jnp.float32

    nq, nk = -(-Sq // qc), -(-Skv // kc)
    pad_q = nq * qc - Sq
    pad_k = nk * kc - Skv

    def to_q_blocks(x):                        # (B,Sq,H,hd) -> (B,nq,qc,KV,g,hd)
        xp = jnp.pad(x.astype(f32), ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        return xp.reshape(B, nq, qc, KV, g, hd)

    qb = to_q_blocks(q) * scale
    dob = to_q_blocks(dout)
    ob = to_q_blocks(out)
    kb = jnp.pad(k.astype(f32), ((0, 0), (0, pad_k), (0, 0), (0, 0))
                 ).reshape(B, nk, kc, KV, hd)
    vb = jnp.pad(v.astype(f32), ((0, 0), (0, pad_k), (0, 0), (0, 0))
                 ).reshape(B, nk, kc, KV, hd)
    lseb = jnp.pad(lse, ((0, 0), (0, 0), (0, 0), (0, pad_q)),
                   constant_values=0.0).reshape(B, KV, g, nq, qc)
    # delta_i = rowsum(dout * out)  (B,KV,g,nq,qc)
    delta = jnp.einsum("bnqkgh,bnqkgh->bkgnq", dob, ob)
    kv_len_eff = jnp.minimum(jnp.asarray(kv_len, jnp.int32), Skv)

    def kv_step(dq_acc, j):
        k_j = kb[:, j]
        v_j = vb[:, j]
        k_pos = j * kc + jnp.arange(kc)

        def q_step(i):
            q_i = qb[:, i]                     # (B,qc,KV,g,hd)
            do_i = dob[:, i]
            s = jnp.einsum("bqkgh,bckh->bkgqc", q_i, k_j)
            q_pos = i * qc + jnp.arange(qc)
            ok = _block_ok(q_pos, k_pos, causal, window, q_offset, kv_len_eff)
            ok = ok & (q_pos < Sq)[:, None]
            p = jnp.where(ok[None, None, None],
                          jnp.exp(s - lseb[:, :, :, i][..., None]), 0.0)
            dv_ij = jnp.einsum("bkgqc,bqkgh->bckh", p, do_i)
            dp = jnp.einsum("bqkgh,bckh->bkgqc", do_i, v_j)
            ds = p * (dp - delta[:, :, :, i][..., None])
            dq_ij = jnp.einsum("bkgqc,bckh->bqkgh", ds, k_j)
            dk_ij = jnp.einsum("bkgqc,bqkgh->bckh", ds, q_i)
            return dq_ij, dk_ij, dv_ij

        if nq == 1:
            dq_all, dk_j, dv_j = q_step(0)
            dq_all = dq_all[:, None]
        else:
            dq_s, dk_s, dv_s = jax.lax.map(q_step, jnp.arange(nq))
            dq_all = jnp.moveaxis(dq_s, 0, 1)          # (B,nq,qc,KV,g,hd)
            # Left-to-right accumulation over i, matching the sequential
            # per-pair adds of the block-skip path bit-for-bit (a vectorized
            # sum() may tree-reduce and round differently); fori_loop keeps
            # the trace O(1) in nq.
            dk_j, dv_j = jax.lax.fori_loop(
                1, nq,
                lambda i, kv: (kv[0] + dk_s[i], kv[1] + dv_s[i]),
                (dk_s[0], dv_s[0]))
        return dq_acc + dq_all, (dk_j, dv_j)

    dq0 = jnp.zeros((B, nq, qc, KV, g, hd), f32)
    dq, (dk_s, dv_s) = jax.lax.scan(kv_step, dq0, jnp.arange(nk))
    dq = (dq * scale).reshape(B, nq * qc, H, hd)[:, :Sq].astype(q.dtype)
    dk = jnp.moveaxis(dk_s, 0, 1).reshape(B, nk * kc, KV, hd)[:, :Skv].astype(k.dtype)
    dv = jnp.moveaxis(dv_s, 0, 1).reshape(B, nk * kc, KV, hd)[:, :Skv].astype(v.dtype)
    return dq, dk, dv, None, None, None


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention_vjp(q: Array, k: Array, v: Array, *, causal: bool,
                        window=None, q_offset=0, kv_len=None,
                        q_chunk: int = 512, kv_chunk: int = 1024,
                        block_skip: bool = False) -> Array:
    """Public entry: chunked flash attention, memory-bounded in both passes.

    block_skip=True (causal only) iterates only the chunk pairs at or below
    the causal diagonal — ~2x fewer attention FLOPs at long sequence."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Skv)
    w = WINDOW_INF if window is None else jnp.asarray(window, jnp.int32)
    kl = jnp.asarray(Skv if kv_len is None else kv_len, jnp.int32)
    qo = jnp.asarray(q_offset, jnp.int32)
    return _flash(qc, kc, causal, bool(block_skip), q, k, v, w, qo, kl)
