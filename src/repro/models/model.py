"""Unified model: schema-driven params, scan-over-layers forward with
train / prefill / decode modes, covering every assigned architecture family.

Param layout: every per-layer tensor is stacked on a leading L axis so the
layer stack is a single ``lax.scan`` — compile time is depth-independent
(essential for the 64-layer 104B dry-run) and FSDP all-gathers exactly one
layer's weights at a time inside the loop.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ModelConfig
from repro.models.flash import flash_attention_vjp
from repro.models.layers import (AttnMask, apply_rope, decode_attention,
                                 flash_attention, mlp, rms_norm, rope_angles)
from repro.parallel.sharding import constrain

Array = jax.Array
COMPUTE_DTYPE = jnp.bfloat16


# ----------------------------------------------------------------- schema
def _schema(cfg: ModelConfig) -> dict[str, tuple[tuple[int, ...], tuple, float]]:
    """name -> (shape, logical axis names, init scale).  Per-layer tensors
    are stacked on a leading L axis (logical name None: replicated)."""
    d, L = cfg.d_model, cfg.num_layers
    H, KV, hd, f = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_ff
    s: dict[str, tuple[tuple[int, ...], tuple, float]] = {}
    emb_scale = 0.02
    w_scale = 0.02
    o_scale = 0.02 / math.sqrt(2 * max(L, 1))

    s["embed"] = ((cfg.vocab_padded, d), ("vocab", "fsdp"), emb_scale)
    s["final_norm"] = ((d,), (None,), 0.0)
    if not cfg.tie_embeddings:
        s["lm_head"] = ((d, cfg.vocab_padded), ("fsdp", "vocab"), emb_scale)

    def attn(prefix: str, cross: bool = False):
        s[f"{prefix}wq"] = ((L, d, H, hd), (None, "fsdp", "heads", "head_dim"), w_scale)
        s[f"{prefix}wk"] = ((L, d, KV, hd), (None, "fsdp", "kv_heads", "head_dim"), w_scale)
        s[f"{prefix}wv"] = ((L, d, KV, hd), (None, "fsdp", "kv_heads", "head_dim"), w_scale)
        s[f"{prefix}wo"] = ((L, H, hd, d), (None, "heads", "head_dim", "fsdp"), o_scale)
        if cfg.qkv_bias and not cross:
            s[f"{prefix}bq"] = ((L, H, hd), (None, "heads", "head_dim"), 0.0)
            s[f"{prefix}bk"] = ((L, KV, hd), (None, "kv_heads", "head_dim"), 0.0)
            s[f"{prefix}bv"] = ((L, KV, hd), (None, "kv_heads", "head_dim"), 0.0)
        if cfg.qk_norm and not cross:
            s[f"{prefix}q_norm"] = ((L, hd), (None, None), 0.0)
            s[f"{prefix}k_norm"] = ((L, hd), (None, None), 0.0)

    def dense_mlp(prefix: str, width: int):
        if cfg.mlp_act in ("silu", "geglu"):
            s[f"{prefix}w_gate"] = ((L, d, width), (None, "fsdp", "mlp"), w_scale)
        s[f"{prefix}w_in"] = ((L, d, width), (None, "fsdp", "mlp"), w_scale)
        s[f"{prefix}w_out"] = ((L, width, d), (None, "mlp", "fsdp"), o_scale)

    def ssm_params(prefix: str):
        sp = cfg.ssm
        d_inner = sp.expand * d
        nh = d_inner // sp.head_dim
        conv_dim = d_inner + 2 * sp.n_groups * sp.d_state
        d_proj = 2 * d_inner + 2 * sp.n_groups * sp.d_state + nh
        s[f"{prefix}in_proj"] = ((L, d, d_proj), (None, "fsdp", "mlp"), w_scale)
        s[f"{prefix}conv_w"] = ((L, sp.conv_width, conv_dim), (None, None, "mlp"), 0.1)
        s[f"{prefix}conv_b"] = ((L, conv_dim), (None, "mlp"), 0.0)
        s[f"{prefix}dt_bias"] = ((L, nh), (None, "heads"), 0.1)
        s[f"{prefix}A_log"] = ((L, nh), (None, "heads"), 0.1)
        s[f"{prefix}D"] = ((L, nh), (None, "heads"), 0.1)
        s[f"{prefix}norm"] = ((L, d_inner), (None, "mlp"), 0.0)
        s[f"{prefix}out_proj"] = ((L, d_inner, d), (None, "mlp", "fsdp"), o_scale)

    s["ln1"] = ((L, d), (None, None), 0.0)
    if cfg.block in ("attn", "hybrid"):
        attn("")
    if cfg.block in ("ssm", "hybrid"):
        ssm_params("ssm_")
    if cfg.moe is not None:
        m = cfg.moe
        E = m.padded_experts()
        s["ln2"] = ((L, d), (None, None), 0.0)
        s["router"] = ((L, d, m.num_experts), (None, "fsdp", None), w_scale)
        s["moe_w_gate"] = ((L, E, d, m.d_ff_expert),
                          (None, "experts", "fsdp", "expert_mlp"), w_scale)
        s["moe_w_in"] = ((L, E, d, m.d_ff_expert),
                        (None, "experts", "fsdp", "expert_mlp"), w_scale)
        s["moe_w_out"] = ((L, E, m.d_ff_expert, d),
                         (None, "experts", "expert_mlp", "fsdp"), o_scale)
        if m.num_shared:
            fs = m.num_shared * m.d_ff_expert
            s["shared_w_gate"] = ((L, d, fs), (None, "fsdp", "mlp"), w_scale)
            s["shared_w_in"] = ((L, d, fs), (None, "fsdp", "mlp"), w_scale)
            s["shared_w_out"] = ((L, fs, d), (None, "mlp", "fsdp"), o_scale)
            s["shared_gate"] = ((L, d, 1), (None, "fsdp", None), w_scale)
    elif cfg.d_ff:
        s["ln2"] = ((L, d), (None, None), 0.0)
        dense_mlp("", f)

    if cfg.enc_dec:
        # encoder stack (bidirectional, no cache) + decoder cross-attention
        Le = cfg.enc_layers
        for nm, shp, names, sc in [
            ("enc_wq", (Le, d, H, hd), (None, "fsdp", "heads", "head_dim"), w_scale),
            ("enc_wk", (Le, d, KV, hd), (None, "fsdp", "kv_heads", "head_dim"), w_scale),
            ("enc_wv", (Le, d, KV, hd), (None, "fsdp", "kv_heads", "head_dim"), w_scale),
            ("enc_wo", (Le, H, hd, d), (None, "heads", "head_dim", "fsdp"), o_scale),
            ("enc_w_in", (Le, d, f), (None, "fsdp", "mlp"), w_scale),
            ("enc_w_out", (Le, f, d), (None, "mlp", "fsdp"), o_scale),
            ("enc_ln1", (Le, d), (None, None), 0.0),
            ("enc_ln2", (Le, d), (None, None), 0.0),
            ("enc_final_norm", (d,), (None,), 0.0),
            ("xattn_wq", (L, d, H, hd), (None, "fsdp", "heads", "head_dim"), w_scale),
            ("xattn_wk", (L, d, KV, hd), (None, "fsdp", "kv_heads", "head_dim"), w_scale),
            ("xattn_wv", (L, d, KV, hd), (None, "fsdp", "kv_heads", "head_dim"), w_scale),
            ("xattn_wo", (L, H, hd, d), (None, "heads", "head_dim", "fsdp"), o_scale),
            ("ln_x", (L, d), (None, None), 0.0),
        ]:
            s[nm] = (shp, names, sc)
    return s


def init_params(cfg: ModelConfig, key: jax.Array) -> dict[str, Array]:
    sch = _schema(cfg)
    keys = jax.random.split(key, len(sch))
    params = {}
    for (name, (shape, _, scale)), k in zip(sorted(sch.items()), keys):
        if scale == 0.0:
            params[name] = jnp.zeros(shape, jnp.float32)
        elif name.endswith(("A_log", "dt_bias", "D")):
            params[name] = jnp.ones(shape, jnp.float32) * 0.5
        else:
            params[name] = jax.random.normal(k, shape, jnp.float32) * scale
    return params


def abstract_params(cfg: ModelConfig) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for the dry-run — no allocation."""
    return {name: jax.ShapeDtypeStruct(shape, jnp.float32)
            for name, (shape, _, _) in _schema(cfg).items()}


def param_logical(cfg: ModelConfig) -> dict[str, tuple]:
    return {name: names for name, (_, names, _) in _schema(cfg).items()}


def global_flags(cfg: ModelConfig) -> np.ndarray:
    """Per-layer bool: True = full/global attention, False = sliding window."""
    if cfg.num_layers == 0:
        return np.zeros(0, bool)
    if cfg.sliding_window is None:
        return np.ones(cfg.num_layers, bool)
    if cfg.global_every is not None:
        return np.array([(i + 1) % cfg.global_every == 0
                         for i in range(cfg.num_layers)])
    # hybrid default (Hymba): first / middle / last layers global
    flags = np.zeros(cfg.num_layers, bool)
    flags[[0, cfg.num_layers // 2, cfg.num_layers - 1]] = True
    return flags


# ------------------------------------------------------------------ caches
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               abstract: bool = False) -> dict[str, Any]:
    """Decode-state pytree.  Full-length KV caches (windows applied as
    masks — memory is fine at the assigned shapes once sharded)."""
    L, KV, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    mk = (jax.ShapeDtypeStruct if abstract
          else lambda s, d: jnp.zeros(s, d))
    cache: dict[str, Any] = {"pos": (jax.ShapeDtypeStruct((), jnp.int32)
                                     if abstract else jnp.zeros((), jnp.int32))}
    if cfg.block in ("attn", "hybrid"):
        cache["k"] = mk((L, batch, max_len, KV, hd), COMPUTE_DTYPE)
        cache["v"] = mk((L, batch, max_len, KV, hd), COMPUTE_DTYPE)
    if cfg.block in ("ssm", "hybrid"):
        sp = cfg.ssm
        d_inner = sp.expand * cfg.d_model
        nh = d_inner // sp.head_dim
        conv_dim = d_inner + 2 * sp.n_groups * sp.d_state
        cache["conv"] = mk((L, batch, sp.conv_width - 1, conv_dim), COMPUTE_DTYPE)
        cache["ssm"] = mk((L, batch, nh, sp.head_dim, sp.d_state), jnp.float32)
    if cfg.enc_dec:
        cache["xk"] = mk((L, batch, cfg.enc_frames, KV, hd), COMPUTE_DTYPE)
        cache["xv"] = mk((L, batch, cfg.enc_frames, KV, hd), COMPUTE_DTYPE)
    return cache


def cache_logical(cfg: ModelConfig) -> dict[str, tuple]:
    names: dict[str, tuple] = {"pos": ()}
    if cfg.block in ("attn", "hybrid"):
        names["k"] = (None, "batch", None, "kv_heads", "head_dim")
        names["v"] = (None, "batch", None, "kv_heads", "head_dim")
    if cfg.block in ("ssm", "hybrid"):
        names["conv"] = (None, "batch", None, "mlp")
        names["ssm"] = (None, "batch", "heads", None, "state")
    if cfg.enc_dec:
        names["xk"] = (None, "batch", None, "kv_heads", "head_dim")
        names["xv"] = (None, "batch", None, "kv_heads", "head_dim")
    return names


# ----------------------------------------------------------------- forward
def _remat_policy(cfg: ModelConfig):
    """"full" saves nothing (recompute the layer in bwd — the flash-attention
    internals must NOT be saved or remat is defeated); "dots" saves matmul
    outputs (cheaper recompute, ~L x more activation memory)."""
    if cfg.remat == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


def _sinusoidal(positions: Array, d: int) -> Array:
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _attention_sub(p, x_norm, cfg, *, mode, angles, is_global, cache_k,
                   cache_v, pos, kv_len, prefix="", cross_kv=None):
    """Shared attention for decoder self-attn, cross-attn and encoder."""
    B, S, _ = x_norm.shape
    dt = x_norm.dtype
    q = jnp.einsum("bsd,dhk->bshk", x_norm, p[f"{prefix}wq"].astype(dt))
    if f"{prefix}bq" in p:
        q = q + p[f"{prefix}bq"].astype(dt)
    if cross_kv is None:
        k = jnp.einsum("bsd,dhk->bshk", x_norm, p[f"{prefix}wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", x_norm, p[f"{prefix}wv"].astype(dt))
        if f"{prefix}bk" in p:
            k = k + p[f"{prefix}bk"].astype(dt)
            v = v + p[f"{prefix}bv"].astype(dt)
    else:
        k, v = cross_kv
    if cfg.qk_norm and f"{prefix}q_norm" in p:
        q = rms_norm(q, p[f"{prefix}q_norm"], cfg.norm_eps)
        k = rms_norm(k, p[f"{prefix}k_norm"], cfg.norm_eps) if cross_kv is None else k
    if angles is not None and cross_kv is None:
        q = apply_rope(q, angles)
        k = apply_rope(k, angles)
    q = constrain(q, ("batch", None, "heads", "head_dim"))

    window = cfg.sliding_window
    new_k = new_v = None
    if mode == "decode" and cross_kv is None:
        new_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, pos, axis=1)
        new_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, pos, axis=1)
        mask = AttnMask(True, window, pos, kv_len)
        mask = _apply_global(mask, is_global)
        out = decode_attention(q, new_k, new_v, mask)
    elif mode == "decode":
        mask = AttnMask(False, None, 0, kv_len)
        out = decode_attention(q, cache_k, cache_v, mask)
    else:
        causal = cross_kv is None
        mask = AttnMask(causal, window if cross_kv is None else None, 0, None)
        mask = _apply_global(mask, is_global)
        skip = cfg.flash_block_skip and mask.causal
        if cfg.ulysses_attn:
            # Ulysses: a2a q to sequence-sharded full-head layout; replicate
            # the (small, GQA) k/v over TP.  Flash then runs without any
            # collective inside its chunk loops.
            q = constrain(q, ("batch", "seq_sp", None, None))
            k = constrain(k, ("batch", None, None, None))
            v = constrain(v, ("batch", None, None, None))
        out = flash_attention_vjp(q, k, v, causal=mask.causal,
                                  window=mask.window, q_offset=0, kv_len=None,
                                  block_skip=skip,
                                  kv_chunk=512 if skip else 1024)
        if cfg.ulysses_attn:
            out = constrain(out, ("batch", None, "heads", "head_dim"))
        if cross_kv is None:
            new_k, new_v = k, v
    y = jnp.einsum("bshk,hkd->bsd", out, p[f"{prefix}wo"].astype(dt))
    return y, (new_k, new_v)


def _apply_global(mask: AttnMask, is_global) -> AttnMask:
    """Per-layer global flag (scanned): a global layer disables the window."""
    if mask.window is None or is_global is None:
        return mask
    if isinstance(is_global, (bool, np.bool_)):
        return mask._replace(window=None) if is_global else mask
    # traced flag: widen the window to "infinite" arithmetically
    window = jnp.where(is_global, jnp.int32(2**30), jnp.int32(mask.window))
    return mask._replace(window=window)


def _decoder_layer(x, p, cfg, *, mode, angles, is_global, cache, pos, kv_len,
                   enc_out=None):
    dt = x.dtype
    if cfg.seq_sharded and mode == "train":
        # Megatron-SP: the carry (and therefore every remat-saved per-layer
        # activation) lives sequence-sharded over the TP axis; attention /
        # matmuls gather what they need transiently inside the layer.
        x = constrain(x, ("batch", "seq_sp", None))
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    mix = jnp.zeros_like(x)
    new_cache = {}

    if cfg.block in ("attn", "hybrid"):
        attn_out, (nk, nv) = _attention_sub(
            p, h, cfg, mode=mode, angles=angles, is_global=is_global,
            cache_k=cache.get("k"), cache_v=cache.get("v"),
            pos=pos, kv_len=kv_len)
        mix = mix + attn_out
        if mode != "train" and nk is not None:
            new_cache["k"] = nk.astype(COMPUTE_DTYPE)
            new_cache["v"] = nv.astype(COMPUTE_DTYPE)

    if cfg.block in ("ssm", "hybrid"):
        ssm_state = ({"conv": cache["conv"].astype(dt), "ssm": cache["ssm"]}
                     if mode == "decode" else None)
        ssm_out, new_state = ssm_lib.mamba2_mix(
            {k[4:]: v for k, v in p.items() if k.startswith("ssm_")},
            h, cfg, mode=("step" if mode == "decode" else "full"),
            state=ssm_state)
        mix = mix + ssm_out
        if mode != "train":
            new_cache["conv"] = new_state["conv"].astype(COMPUTE_DTYPE)
            new_cache["ssm"] = new_state["ssm"]

    if cfg.block == "hybrid":
        mix = mix * 0.5                       # average the parallel heads

    if cfg.enc_dec:
        # cross-attention (cache holds projected encoder K/V)
        xh = rms_norm(x + mix, p["ln_x"], cfg.norm_eps)
        if mode != "decode":
            xk = jnp.einsum("bsd,dhk->bshk", enc_out, p["xattn_wk"].astype(dt))
            xv = jnp.einsum("bsd,dhk->bshk", enc_out, p["xattn_wv"].astype(dt))
        else:
            xk, xv = cache["xk"], cache["xv"]
        xattn, _ = _attention_sub(
            p, xh, cfg, mode=("decode" if mode == "decode" else "train"),
            angles=None, is_global=None, cache_k=xk, cache_v=xv,
            pos=pos, kv_len=None, prefix="xattn_", cross_kv=(xk, xv))
        mix = mix + xattn
        if mode != "train":
            new_cache["xk"], new_cache["xv"] = xk, xv

    if cfg.parallel_block and cfg.moe is None and cfg.d_ff:
        y = x + mix + mlp(h, {k2: p[k2] for k2 in ("w_in", "w_gate", "w_out")
                              if k2 in p}, cfg.mlp_act)
        if cfg.seq_sharded and mode == "train":
            y = constrain(y, ("batch", "seq_sp", None))
        return y, new_cache

    x = x + mix
    if cfg.moe is not None:
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        mo = {"router": p["router"], "w_gate": p["moe_w_gate"],
              "w_in": p["moe_w_in"], "w_out": p["moe_w_out"]}
        for nm in ("shared_w_gate", "shared_w_in", "shared_w_out", "shared_gate"):
            if nm in p:
                mo[nm] = p[nm]
        y = x + moe_lib.moe_ffn(h2, mo, cfg.moe, cfg.mlp_act)
    elif cfg.d_ff:
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        y = x + mlp(h2, p, cfg.mlp_act)
    else:
        y = x
    if cfg.seq_sharded and mode == "train":
        y = constrain(y, ("batch", "seq_sp", None))
    return y, new_cache


def _encoder(params, cfg, frames: Array) -> Array:
    """Whisper-style encoder over precomputed frame embeddings (conv
    frontend is a stub per the assignment): bidirectional attention."""
    B, F, d = frames.shape
    x = (frames + _sinusoidal(jnp.arange(F)[None].repeat(B, 0), d)
         ).astype(COMPUTE_DTYPE)

    def body(x, lp):
        h = rms_norm(x, lp["enc_ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["enc_wq"].astype(x.dtype))
        k = jnp.einsum("bsd,dhk->bshk", h, lp["enc_wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", h, lp["enc_wv"].astype(x.dtype))
        out = flash_attention_vjp(q, k, v, causal=False)
        x = x + jnp.einsum("bshk,hkd->bsd", out, lp["enc_wo"].astype(x.dtype))
        h2 = rms_norm(x, lp["enc_ln2"], cfg.norm_eps)
        hh = jnp.einsum("bsd,df->bsf", h2, lp["enc_w_in"].astype(x.dtype))
        hh = jax.nn.gelu(hh, approximate=True)
        x = x + jnp.einsum("bsf,fd->bsd", hh, lp["enc_w_out"].astype(x.dtype))
        return x, None

    layer_params = {k: v for k, v in params.items()
                    if k.startswith("enc_") and k != "enc_final_norm"}
    body_fn = body
    if cfg.remat != "none":
        body_fn = jax.checkpoint(body, policy=_remat_policy(cfg))
    x, _ = jax.lax.scan(body_fn, x, layer_params)
    return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


_LAYER_KEYS_CACHE: dict[str, tuple] = {}


def _split_layer_params(params: dict, cfg: ModelConfig):
    """Split the flat param dict into (global, stacked-per-layer) parts."""
    enc = {"enc_wq", "enc_wk", "enc_wv", "enc_wo", "enc_w_in", "enc_w_out",
           "enc_ln1", "enc_ln2"}
    glob = {"embed", "final_norm", "lm_head", "enc_final_norm"}
    layer = {k: v for k, v in params.items()
             if k not in glob and k not in enc}
    return layer


def model_forward(params: dict, cfg: ModelConfig, tokens: Array, *,
                  visual: Array | None = None,
                  mrope_positions: Array | None = None,
                  frames: Array | None = None,
                  mode: str = "train",
                  cache: dict | None = None,
                  max_len: int | None = None,
                  return_hidden: bool = False):
    """Returns (logits, new_cache).

    train   : tokens (B, S) -> logits (B, S, Vp), cache None
    prefill : tokens (B, S) -> last-position logits (B, 1, Vp) + cache
    decode  : tokens (B, 1) + cache -> logits (B, 1, Vp) + cache
    """
    B, S = tokens.shape
    dt = COMPUTE_DTYPE
    pos0 = cache["pos"] if (cache is not None and mode == "decode") else 0

    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    if cfg.vlm and visual is not None:
        V = visual.shape[1]
        vis = jnp.pad(visual.astype(dt), ((0, 0), (0, S - V), (0, 0)))
        is_vis = (jnp.arange(S) < V)[None, :, None]
        x = jnp.where(is_vis, vis, x)
    x = constrain(x, ("batch", "seq", "embed"))

    # positions / rope angles
    if cfg.rope == "mrope":
        if mrope_positions is None:
            base = pos0 + jnp.arange(S)[None]
            mrope_positions = jnp.broadcast_to(base, (3, B, S))
        angles = rope_angles(mrope_positions, cfg.head_dim, cfg.rope_theta,
                             cfg.mrope_sections)
    elif cfg.rope == "rope":
        positions = pos0 + jnp.arange(S)[None]
        angles = rope_angles(jnp.broadcast_to(positions, (B, S)),
                             cfg.head_dim, cfg.rope_theta)
    else:
        angles = None

    enc_out = None
    if cfg.enc_dec and frames is not None:
        enc_out = _encoder(params, cfg, frames)

    flags = jnp.asarray(global_flags(cfg))
    layer_params = _split_layer_params(params, cfg)
    kv_len = (pos0 + 1) if mode == "decode" else None

    def body(x, scanned):
        lp, flag, layer_cache = scanned
        y, new_cache = _decoder_layer(
            x, lp, cfg, mode=mode, angles=angles, is_global=flag,
            cache=layer_cache, pos=pos0, kv_len=kv_len, enc_out=enc_out)
        return y, new_cache

    body_fn = body
    if cfg.remat != "none" and mode == "train":
        body_fn = jax.checkpoint(body, policy=_remat_policy(cfg))

    if cache is not None:
        layer_caches = {k: v for k, v in cache.items() if k != "pos"}
    else:
        layer_caches = _empty_caches(cfg, B, S)

    x, new_caches = jax.lax.scan(body_fn, x, (layer_params, flags, layer_caches))

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if mode == "train" and return_hidden:
        return x, None
    if mode == "prefill":
        x = x[:, -1:]
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(dt))
    logits = constrain(logits, ("batch", None, "vocab"))
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c

    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = dict(new_caches)
        if mode == "prefill" and max_len is not None and max_len > S:
            for nm in ("k", "v"):
                if nm in new_cache:
                    pad = [(0, 0)] * new_cache[nm].ndim
                    pad[2] = (0, max_len - S)
                    new_cache[nm] = jnp.pad(new_cache[nm], pad)
        new_cache["pos"] = ((pos0 + 1) if mode == "decode"
                            else jnp.asarray(S, jnp.int32))
    return logits, new_cache


def _empty_caches(cfg: ModelConfig, B: int, S: int) -> dict:
    """Per-layer cache placeholders for train/prefill scan xs (zero-size
    where the mode produces the cache itself)."""
    out: dict[str, Array] = {}
    L = cfg.num_layers
    if cfg.block in ("ssm", "hybrid"):
        sp = cfg.ssm
        d_inner = sp.expand * cfg.d_model
        nh = d_inner // sp.head_dim
        conv_dim = d_inner + 2 * sp.n_groups * sp.d_state
        out["conv"] = jnp.zeros((L, B, sp.conv_width - 1, conv_dim), COMPUTE_DTYPE)
        out["ssm"] = jnp.zeros((L, B, nh, sp.head_dim, sp.d_state), jnp.float32)
    if cfg.block in ("attn", "hybrid"):
        out["k"] = jnp.zeros((L, B, 0, cfg.num_kv_heads, cfg.head_dim), COMPUTE_DTYPE)
        out["v"] = jnp.zeros((L, B, 0, cfg.num_kv_heads, cfg.head_dim), COMPUTE_DTYPE)
    if cfg.enc_dec:
        out["xk"] = jnp.zeros((L, B, 0, cfg.num_kv_heads, cfg.head_dim), COMPUTE_DTYPE)
        out["xv"] = jnp.zeros((L, B, 0, cfg.num_kv_heads, cfg.head_dim), COMPUTE_DTYPE)
    return out


# -------------------------------------------------------------------- loss
def lm_loss(params: dict, cfg: ModelConfig, batch: dict) -> tuple[Array, dict]:
    """Next-token cross-entropy via the fused vocab-parallel chunked loss —
    full (B, S, V) logits are never materialized (see models/loss.py)."""
    from repro.models.loss import fused_ce_loss
    hidden, _ = model_forward(
        params, cfg, batch["tokens"],
        visual=batch.get("visual"), mrope_positions=batch.get("mrope_positions"),
        frames=batch.get("frames"), mode="train", return_hidden=True)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    loss, tokens = fused_ce_loss(
        hidden, head.astype(hidden.dtype), batch["labels"],
        valid_vocab=cfg.vocab_size)
    return loss, {"loss": loss, "tokens": tokens}
