from repro.models.config import ModelConfig, MoESpec, SSMSpec  # noqa: F401
