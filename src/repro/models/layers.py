"""Transformer building blocks: norms, rotary embeddings (RoPE / M-RoPE),
gated MLPs, and a chunked flash-style attention that is memory-bounded at
any sequence length (pure JAX — compiles on CPU for the dry-run and on TPU;
a Pallas flash kernel in kernels/attention.py can replace it at runtime).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain

Array = jax.Array
NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ------------------------------------------------------------------- norms
def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))
            ).astype(dt)


# -------------------------------------------------------------------- rope
def rope_angles(positions: Array, head_dim: int, theta: float,
                mrope_sections: tuple[int, ...] | None = None) -> Array:
    """positions: (B, S) for RoPE or (3, B, S) for M-RoPE -> (B, S, hd/2).

    M-RoPE (Qwen2-VL): the hd/2 frequency slots are split into sections
    (temporal, height, width); slot i takes its position from the stream its
    section belongs to.  Text tokens carry identical streams, so M-RoPE
    degenerates to RoPE for them.
    """
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    if positions.ndim == 2:                       # plain RoPE
        return positions[..., None].astype(jnp.float32) * inv_freq
    assert mrope_sections is not None and sum(mrope_sections) == half
    stream_of_slot = jnp.repeat(
        jnp.arange(len(mrope_sections)),
        jnp.asarray(mrope_sections),
        total_repeat_length=half)                 # (half,)
    pos = jnp.take(positions, stream_of_slot, axis=0)      # (half, B, S)
    return jnp.moveaxis(pos, 0, -1).astype(jnp.float32) * inv_freq


def apply_rope(x: Array, angles: Array) -> Array:
    """x: (B, S, H, hd), angles: (B, S, hd/2) — rotate-half convention."""
    dt = x.dtype
    half = x.shape[-1] // 2
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(dt)


# --------------------------------------------------------------------- mlp
def mlp(x: Array, p: dict, act: str) -> Array:
    """Gated (silu/geglu) or plain (gelu) MLP.  Weights: w_in/w_gate (d, f),
    w_out (f, d)."""
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"].astype(x.dtype))
    if act in ("silu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g, approximate=True)
        h = g * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    h = constrain(h, ("batch", None, "mlp"))
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"].astype(x.dtype))


# --------------------------------------------------------------- attention
class AttnMask(NamedTuple):
    """Static attention-mask description, applied blockwise inside flash."""
    causal: bool
    window: int | None          # sliding window size (None = unbounded)
    q_offset: int | Array       # absolute position of q[0] (decode: pos)
    kv_len: int | Array | None  # valid kv length (decode: pos + 1)


def _block_mask(q_pos: Array, k_pos: Array, m: AttnMask) -> Array:
    """(Sq, Sk) bool — True where attention is allowed."""
    q_abs = q_pos + m.q_offset
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if m.causal:
        ok &= k_pos[None, :] <= q_abs[:, None]
    if m.window is not None:
        ok &= k_pos[None, :] > (q_abs[:, None] - m.window)
    if m.kv_len is not None:
        ok &= k_pos[None, :] < m.kv_len
    return ok


def flash_attention(q: Array, k: Array, v: Array, mask: AttnMask,
                    *, q_chunk: int = 512, kv_chunk: int = 1024) -> Array:
    """Chunked online-softmax attention.

    q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd) with H % KV == 0.
    Memory is O(Sq * kv_chunk) per head instead of O(Sq * Skv).
    """
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    groups = H // KV
    scale = 1.0 / math.sqrt(hd)

    q = q.astype(jnp.float32) * scale
    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Skv)
    nq, nk = -(-Sq // qc), -(-Skv // kc)
    # pad to chunk multiples
    qp = jnp.pad(q, ((0, 0), (0, nq * qc - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * kc - Skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * kc - Skv), (0, 0), (0, 0)))
    # (B, nq, qc, KV, g, hd) view of q
    qv = qp.reshape(B, nq, qc, KV, groups, hd)
    kv_ = kp.reshape(B, nk, kc, KV, hd)
    vv = vp.reshape(B, nk, kc, KV, hd)

    def q_block(i, q_i):
        # q_i: (B, qc, KV, g, hd)
        q_pos = i * qc + jnp.arange(qc)

        def kv_step(carry, j):
            acc, m_run, d_run = carry
            k_j = kv_[:, j].astype(jnp.float32)          # (B, kc, KV, hd)
            v_j = vv[:, j].astype(jnp.float32)
            s = jnp.einsum("bqkgh,bckh->bkgqc", q_i, k_j)  # (B,KV,g,qc,kc)
            k_pos = j * kc + jnp.arange(kc)
            ok = _block_mask(q_pos, k_pos, mask)           # (qc, kc)
            ok &= (k_pos < Skv)[None, :]
            s = jnp.where(ok[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            d_new = d_run * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqc,bckh->bkgqh", p, v_j)
            acc = acc * corr[..., None] + pv
            return (acc, m_new, d_new), None

        acc0 = jnp.zeros((B, KV, groups, qc, hd), jnp.float32)
        m0 = jnp.full((B, KV, groups, qc), NEG_INF, jnp.float32)
        d0 = jnp.zeros((B, KV, groups, qc), jnp.float32)
        (acc, m_run, d_run), _ = jax.lax.scan(
            kv_step, (acc0, m0, d0), jnp.arange(nk))
        out = acc / jnp.maximum(d_run[..., None], 1e-37)
        return out                                        # (B, KV, g, qc, hd)

    if nq == 1:
        out = q_block(0, qv[:, 0])[:, :, :, None]         # add nq axis
        out = jnp.moveaxis(out, 3, 1)
    else:
        outs = jax.lax.map(lambda args: q_block(*args),
                           (jnp.arange(nq), jnp.moveaxis(qv, 1, 0)))
        out = jnp.moveaxis(outs, 0, 3)                    # (B,KV,g,nq,qc,hd)
    # (B, KV, g, nq, qc, hd) -> (B, Sq, H, hd)
    out = out.reshape(B, KV, groups, nq * qc, hd)
    out = jnp.moveaxis(out, 3, 1).reshape(B, nq * qc, H, hd)
    return out[:, :Sq].astype(k.dtype)


def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     mask: AttnMask) -> Array:
    """Single-position attention against a (possibly padded) KV cache.

    q: (B, 1, H, hd); caches: (B, Smax, KV, hd)."""
    B, _, H, hd = q.shape
    _, Smax, KV, _ = k_cache.shape
    groups = H // KV
    scale = 1.0 / math.sqrt(hd)
    qv = (q.astype(jnp.float32) * scale).reshape(B, KV, groups, hd)
    s = jnp.einsum("bkgh,bckh->bkgc", qv, k_cache.astype(jnp.float32))
    k_pos = jnp.arange(Smax)
    ok = _block_mask(jnp.zeros((1,), jnp.int32), k_pos, mask)[0]   # (Smax,)
    s = jnp.where(ok[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckh->bkgh", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(k_cache.dtype)
