"""Unified architecture description covering all assigned families:
dense / GQA / MQA, MoE (shared+routed), SSM (Mamba2 SSD), hybrid (Hymba),
encoder-decoder (Whisper), VLM prefix (Qwen2-VL M-RoPE), local:global
sliding-window patterns (Gemma3)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0          # shared experts, fused into one wide MLP
    capacity_factor: float = 1.25
    router_norm: bool = False    # granite normalizes top-k gate weights
    ep_pad: bool = False         # pad expert count to the EP axis size so
                                 # experts shard (60->64, 40->48 on TP=16);
                                 # padded experts receive no tokens.

    def padded_experts(self, axis: int = 16) -> int:
        if not self.ep_pad:
            return self.num_experts
        return -(-self.num_experts // axis) * axis


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    block: str = "attn"              # attn | ssm | hybrid
    mlp_act: str = "silu"            # silu (gated) | gelu | geglu (gated gelu)
    qkv_bias: bool = False
    parallel_block: bool = False     # command-r: attn and mlp from one norm
    rope: str = "rope"               # rope | mrope | none
    rope_theta: float = 1e4
    mrope_sections: tuple[int, ...] = (16, 24, 24)
    sliding_window: int | None = None
    global_every: int | None = None  # gemma3: every Nth layer is global
    qk_norm: bool = False
    logit_softcap: float | None = None
    moe: MoESpec | None = None
    ssm: SSMSpec | None = None
    enc_dec: bool = False
    enc_layers: int = 0
    enc_frames: int = 1500           # whisper 30 s window
    vlm: bool = False
    visual_prefix: int = 1024        # patch-embedding positions at seq start
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    remat: str = "full"              # none | dots | full (full: recompute the
                                     # layer in bwd — saves only (B,S,d)/layer)
    flash_block_skip: bool = False   # causal chunk skipping (~2x attn FLOPs)
    seq_sharded: bool = False        # shard the residual stream's sequence
                                     # dim over the TP axis (Megatron-SP):
                                     # remat-saved activations / 16
    ulysses_attn: bool = False       # DeepSpeed-Ulysses: reshard q to
                                     # sequence-sharded full-head layout for
                                     # flash (a2a) instead of head_dim TP —
                                     # removes per-block score psums when
                                     # head counts don't divide the TP axis
    # description metadata
    family: str = "dense"
    source: str = ""

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to 256 so the TP axis always divides (Megatron-style)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def layer_is_global(self, i: int) -> bool:
        if self.sliding_window is None:
            return True
        if self.global_every is None:
            return False
        return (i + 1) % self.global_every == 0

    def param_count(self) -> int:
        """Total parameters (embedding included once if tied)."""
        d, f, L = self.d_model, self.d_ff, self.num_layers
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.block in ("attn", "hybrid"):
            per_layer += d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
        if self.block in ("ssm", "hybrid"):
            s = self.ssm
            d_inner = s.expand * d
            nh = d_inner // s.head_dim
            conv_dim = d_inner + 2 * s.n_groups * s.d_state
            per_layer += (d * (2 * d_inner + 2 * s.n_groups * s.d_state + nh)
                          + s.conv_width * conv_dim + 3 * nh
                          + d_inner + d_inner * d)
        if self.moe is not None:
            m = self.moe
            per_layer += d * m.num_experts
            per_layer += m.num_experts * 3 * d * m.d_ff_expert
            if m.num_shared:
                fs = m.num_shared * m.d_ff_expert
                per_layer += 3 * d * fs + d
        elif f:
            gates = 2 if self.mlp_act in ("silu", "geglu") else 1
            per_layer += (gates + 1) * d * f
        per_layer += 2 * d
        n += L * per_layer
        if self.enc_dec:
            enc_per = 2 * (d * self.q_dim + self.q_dim * d) // 2  # self-attn
            # encoder self-attn + mlp + cross-attn params in decoder
            n += self.enc_layers * (d * (self.q_dim + 2 * self.kv_dim)
                                    + self.q_dim * d + 2 * d * f + 2 * d)
            n += L * (d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d + d)
            del enc_per
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: shared + top_k routed)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        d, L = self.d_model, self.num_layers
        inactive = L * (m.num_experts - m.top_k) * 3 * d * m.d_ff_expert
        return self.param_count() - inactive
