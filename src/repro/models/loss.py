"""Fused, vocab-parallel cross-entropy (the "never materialize the logits"
loss).

Why: with 152k-262k vocabs, (B, S, V) logits in f32 are multi-GB per device
and their gradient doubles it; the tied-embedding gradient additionally
all-reduces a replicated (d, V) f32 buffer per microbatch.  This module
computes the loss in sequence chunks inside a shard_map:

  * logits exist only as (B_l, chunk, V_l) blocks in VMEM-sized pieces;
  * logsumexp / gold-logit reductions psum over the ``model`` (vocab) axis;
  * dx is reconstructed chunk-by-chunk in the custom backward;
  * the head gradient accumulates locally over chunks and leaves the device
    ONCE per step via reduce-scatter onto its FSDP shard (not AR + slice).

Falls back to a single-device path when no mesh is active (CPU smoke tests).
"""
from __future__ import annotations

import functools

from repro import compat  # noqa: F401  (get_abstract_mesh / shard_map shims)

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array


def _ce_core(x, head, labels, valid_vocab: int, chunk: int,
             tp_axis: str | None, dp_axes: tuple[str, ...]):
    """Local (per-shard) fused CE with optional collective reductions.
    x (B, S, d); head (d, V_l); labels (B, S) (-1 = masked).
    Returns (nll_sum, token_count, lse (B, S)) — all pre-dp-reduction."""
    B, S, d = x.shape
    V_l = head.shape[1]
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    v_off = (jax.lax.axis_index(tp_axis) * V_l) if tp_axis else 0
    v_ids = v_off + jnp.arange(V_l)
    v_valid = (v_ids < valid_vocab)

    def one_chunk(c):
        x_c = jax.lax.dynamic_slice_in_dim(x, c * chunk, chunk, axis=1)
        l_c = jax.lax.dynamic_slice_in_dim(labels, c * chunk, chunk, axis=1)
        logits = jnp.einsum("bsd,dv->bsv", x_c, head,
                            preferred_element_type=jnp.float32)
        logits = jnp.where(v_valid[None, None], logits, -jnp.inf)
        m = logits.max(axis=-1)
        if tp_axis:
            m = jax.lax.pmax(m, tp_axis)
        z = jnp.exp(logits - m[..., None]).sum(axis=-1)
        if tp_axis:
            z = jax.lax.psum(z, tp_axis)
        lse = m + jnp.log(z)
        l_loc = l_c - v_off
        in_shard = (l_loc >= 0) & (l_loc < V_l)
        gold_l = jnp.take_along_axis(
            logits, jnp.clip(l_loc, 0, V_l - 1)[..., None], axis=-1)[..., 0]
        gold = jnp.where(in_shard, gold_l, 0.0)
        if tp_axis:
            gold = jax.lax.psum(gold, tp_axis)
        mask = (l_c >= 0)
        nll = jnp.where(mask, lse - gold, 0.0)
        return nll.sum(), mask.sum(), lse

    sums, counts, lses = [], [], []
    for c in range(nc):           # static chunk count; bodies are small
        s_, n_, lse_ = one_chunk(c)
        sums.append(s_)
        counts.append(n_)
        lses.append(lse_)
    lse = jnp.concatenate(lses, axis=1)[:, :S]
    return sum(sums), sum(counts), lse


def _make_local_loss(valid_vocab: int, chunk: int, tp_axis, dp_axes):

    @jax.custom_vjp
    def local_loss(x, head, labels):
        nll, cnt, _ = _ce_core(x, head, labels, valid_vocab, chunk,
                               tp_axis, dp_axes)
        return _finalize(nll, cnt)

    def _finalize(nll, cnt):
        nll = nll.astype(jnp.float32)
        cnt = cnt.astype(jnp.float32)
        for ax in dp_axes:
            nll = jax.lax.psum(nll, ax)
            cnt = jax.lax.psum(cnt, ax)
        return nll / jnp.maximum(cnt, 1.0), cnt

    def fwd(x, head, labels):
        nll, cnt, lse = _ce_core(x, head, labels, valid_vocab, chunk,
                                 tp_axis, dp_axes)
        loss, cnt_g = _finalize(nll, cnt)
        return (loss, cnt_g), (x, head, labels, lse, cnt_g)

    def bwd(res, g):
        x, head, labels, lse, cnt_g = res
        gl, _ = g
        B, S, d = x.shape
        V_l = head.shape[1]
        nc = -(-S // chunk)
        pad = nc * chunk - S
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
            lse = jnp.pad(lse, ((0, 0), (0, pad)))
        v_off = (jax.lax.axis_index(tp_axis) * V_l) if tp_axis else 0
        v_ids = v_off + jnp.arange(V_l)
        v_valid = (v_ids < valid_vocab)
        w = gl / jnp.maximum(cnt_g, 1.0)

        dx_chunks = []
        dhead = jnp.zeros(head.shape, jnp.float32)
        for c in range(nc):
            x_c = jax.lax.dynamic_slice_in_dim(x, c * chunk, chunk, axis=1)
            l_c = jax.lax.dynamic_slice_in_dim(labels, c * chunk, chunk, axis=1)
            lse_c = jax.lax.dynamic_slice_in_dim(lse, c * chunk, chunk, axis=1)
            logits = jnp.einsum("bsd,dv->bsv", x_c, head,
                                preferred_element_type=jnp.float32)
            logits = jnp.where(v_valid[None, None], logits, -jnp.inf)
            p = jnp.exp(logits - lse_c[..., None])
            l_loc = l_c - v_off
            onehot = (l_loc[..., None] == jnp.arange(V_l)[None, None])
            mask = (l_c >= 0).astype(jnp.float32)
            dlogits = (p - onehot.astype(jnp.float32)) * (w * mask)[..., None]
            dlogits = jnp.where(v_valid[None, None], dlogits, 0.0)
            dx_c = jnp.einsum("bsv,dv->bsd", dlogits,
                              head.astype(jnp.float32))
            if tp_axis:
                dx_c = jax.lax.psum(dx_c, tp_axis)
            dx_chunks.append(dx_c.astype(x.dtype))
            dhead = dhead + jnp.einsum("bsd,bsv->dv",
                                       x_c.astype(jnp.float32), dlogits)
        dx = jnp.concatenate(dx_chunks, axis=1)[:, :S]
        # head grad leaves the device once: reduce-scatter onto the FSDP
        # shard of d (dp_axes) would change the local shape, so psum here
        # and let the partitioner keep it sharded via the grad constraint.
        for ax in dp_axes:
            dhead = jax.lax.psum(dhead, ax)
        return dx, dhead.astype(head.dtype), None

    local_loss.defvjp(fwd, bwd)
    return local_loss


def fused_ce_loss(x: Array, head: Array, labels: Array, *,
                  valid_vocab: int, chunk: int = 1024
                  ) -> tuple[Array, Array]:
    """Mean next-token NLL over labels >= 0.  x (B,S,d), head (d, Vp).
    Returns (loss, token_count)."""
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty:
        fn = _make_local_loss(valid_vocab, chunk, None, ())
        return fn(x, head, labels)

    names = mesh.axis_names
    sizes = dict(mesh.shape)
    dp = tuple(a for a in ("pod", "data") if a in names and sizes[a] > 1)
    tp = "model" if "model" in names and sizes["model"] > 1 else None
    B, S, d = x.shape
    Vp = head.shape[1]
    dp_div = 1
    for a in dp:
        dp_div *= sizes[a]
    if B % max(dp_div, 1) or (tp and Vp % sizes["model"]):
        fn = _make_local_loss(valid_vocab, chunk, None, ())
        return fn(x, head, labels)

    fn = _make_local_loss(valid_vocab, chunk, tp, dp)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    mapped = jax.shard_map(
        fn, mesh=mesh,
        in_specs=(P(dp_spec, None, None), P(None, tp), P(dp_spec, None)),
        out_specs=(P(), P()),
        check_vma=False)
    return mapped(x, head, labels)
