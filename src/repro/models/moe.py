"""Mixture-of-Experts FFN: top-k routing with capacity buffers.

Dispatch strategy (TPU-minded): instead of the classic (tokens x experts x
capacity) one-hot einsum — whose dispatch tensor is O(T*E*C) and explodes at
32k sequences — assignments are sorted by expert and scattered into a dense
(E, C, d_model) buffer, giving a static-shape grouped GEMM that the MXU
likes and GSPMD can shard (tokens over ``data``, expert FFN over ``model``).
Overflow beyond capacity is dropped (standard capacity-factor semantics);
the smoke tests check conservation when capacity is ample.

Shared experts (Qwen2-MoE, Granite-MoE) are fused into one wide gated MLP
with a sigmoid gate, matching the reference implementations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain

Array = jax.Array


def _capacity(T: int, top_k: int, num_experts: int, factor: float) -> int:
    c = int(T * top_k * factor / num_experts) + 1
    return -(-c // 8) * 8     # pad to 8 for lane alignment


def moe_ffn(x: Array, p: dict, spec, act: str = "silu") -> Array:
    """x (B, S, d) -> (B, S, d).  p: router (d, E); experts w_gate/w_in
    (E, d, fe), w_out (E, fe, d); optional shared_* for shared experts."""
    B, S, d = x.shape
    T = B * S
    E, k = spec.num_experts, spec.top_k
    E_buf = spec.padded_experts()     # >= E; padded experts get no tokens
    C = _capacity(T, k, E, spec.capacity_factor)

    xf = x.reshape(T, d)
    logits = jnp.einsum("td,de->te", xf, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (T, k)
    if spec.router_norm:
        gate_vals = gate_vals / gate_vals.sum(axis=-1, keepdims=True)

    # Flatten assignments and rank them within their expert.
    a_expert = expert_idx.reshape(-1)                         # (A,) A = T*k
    a_token = jnp.repeat(jnp.arange(T), k)
    a_gate = gate_vals.reshape(-1)
    order = jnp.argsort(a_expert, stable=True)
    sorted_expert = a_expert[order]
    # position within expert: index in sorted order minus expert start
    counts = jnp.bincount(a_expert, length=E)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(T * k) - starts[sorted_expert]
    pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)
    keep = pos < C

    # Scatter tokens into the (E_buf, C, d) buffer; dropped tokens go
    # nowhere.  With ep_pad, E_buf divides the TP axis and the buffer (and
    # expert weights) shard expert-parallel.
    slot = jnp.where(keep, a_expert * C + pos, E_buf * C)     # OOB -> dropped
    buf = jnp.zeros((E_buf * C + 1, d), x.dtype).at[slot].set(
        xf[a_token], mode="drop")
    buf = buf[:-1].reshape(E_buf, C, d)
    buf = constrain(buf, ("experts", "batch", None))

    # Grouped expert GEMMs (E batched), TP on the expert hidden dim.
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_in"].astype(x.dtype))
    if act in ("silu", "geglu"):
        g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x.dtype))
        g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g, approximate=True)
        h = g * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    h = constrain(h, ("experts", "batch", "expert_mlp"))
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(x.dtype))
    y_buf = y_buf.reshape(E_buf * C, d)

    # Gather back with gate weights (dropped tokens contribute 0).
    contrib = y_buf[jnp.minimum(slot, E_buf * C - 1)] * (
        a_gate * keep).astype(x.dtype)[:, None]
    out = jnp.zeros((T, d), x.dtype).at[a_token].add(contrib)

    if "shared_w_in" in p:
        sh = {"w_in": p["shared_w_in"], "w_gate": p["shared_w_gate"],
              "w_out": p["shared_w_out"]}
        from repro.models.layers import mlp
        shared = mlp(x, sh, act)
        sgate = jax.nn.sigmoid(
            jnp.einsum("bsd,dz->bsz", x, p["shared_gate"].astype(x.dtype)))
        out = out.reshape(B, S, d) + sgate * shared
        return out
    return out.reshape(B, S, d)
