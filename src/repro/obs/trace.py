"""Span-based tracing with the fault-seam cost model: off = one check.

The serving stack is asynchronous end to end — a query crosses the
submitting thread (admission), the scheduler thread (queue wait, wave
coalescing, bucketed dispatch, reassembly), and possibly the maintenance
worker (a spill its wave triggered) — so a latency number alone cannot
say *where* a slow query spent its time.  This module is the span
substrate the whole stack shares:

  * :class:`Tracer` — explicit-clock (inject a fake clock in tests),
    thread-safe, bounded: finished spans land in a ring buffer
    (overflow counts into :attr:`Tracer.dropped`, never grows).
  * **per-thread span stack** — ``with tracer.span("name"):`` parents
    nested spans automatically on one thread; cross-thread handoffs pass
    an explicit ``parent=`` (a :class:`Span` or its ``(trace, span)``
    context tuple), which is how a maintenance task or a coalesced wave
    chains to the query that caused it.
  * **module-level install** — exactly like :mod:`repro.fault.seam`:
    instrumented sites read one module global (:data:`TRACER`) and take
    a ``None`` branch when tracing is off.  That single attribute check
    is the entire disabled-path cost.

Span taxonomy (the contract ARCHITECTURE.md documents)::

    admission            submit() entry -> enqueued          (per query)
    queue                enqueued -> wave picked it up       (per query)
    serve                dispatch start -> future resolved   (per query,
                         attrs: wave, mode, pj)
    coalesce             one wave end to end                 (per wave)
    device.execute       materialize + block_until_ready     (per wave)
    bucket.dispatch      one bucketed executor call          (per bucket)
    reassembly           result slicing + future resolution  (per wave)
    maintenance.<kind>   one spill/compact/gc/scrub task
    store.*              segment prepare/commit/merge/scrub/gc/repair
    spill.*              indexer-side two-phase spill
    fault.<kind>         zero-duration event where an injected fault hit

Stdlib-only: importable from the very bottom of the stack (the fault
injector and the WAL both hook in) without cycles or heavy imports.
"""
from __future__ import annotations

import collections
import itertools
import threading
import time
from typing import Callable

__all__ = ["Span", "Tracer", "TRACER", "install", "uninstall",
           "current_context", "maybe_span"]


class Span:
    """One timed operation.  ``t1 is None`` while live; ``attrs`` carry
    the site's structured context (wave id, backend, pJ, ...)."""

    __slots__ = ("name", "trace_id", "span_id", "parent_id", "t0", "t1",
                 "attrs")

    def __init__(self, name: str, trace_id: int, span_id: int,
                 parent_id: int, t0: float, attrs: dict):
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.t0 = t0
        self.t1: float | None = None
        self.attrs = attrs

    @property
    def context(self) -> tuple[int, int]:
        """The ``(trace_id, span_id)`` handle a cross-thread child
        passes as ``parent=``."""
        return (self.trace_id, self.span_id)

    @property
    def duration_s(self) -> float:
        return 0.0 if self.t1 is None else self.t1 - self.t0

    def to_dict(self) -> dict:
        return {"name": self.name, "trace": self.trace_id,
                "span": self.span_id, "parent": self.parent_id,
                "t0": self.t0, "t1": self.t1,
                "dur_ms": self.duration_s * 1e3, "attrs": self.attrs}

    def __repr__(self) -> str:
        state = "live" if self.t1 is None else f"{self.duration_s*1e3:.3f}ms"
        return (f"<Span {self.name} trace={self.trace_id} "
                f"span={self.span_id} {state}>")


def _ctx_of(parent) -> tuple[int, int] | None:
    if parent is None:
        return None
    if isinstance(parent, Span):
        return parent.context
    return (int(parent[0]), int(parent[1]))      # (trace, span) tuple


class Tracer:
    """Explicit-clock span recorder (see module docstring).

    ``clock`` is any ``() -> float``; the default is
    ``time.perf_counter`` so span times line up with the service's
    latency meters.  ``capacity`` bounds the finished-span ring;
    ``sink`` optionally receives every finished span's dict (e.g. a
    line-buffered JSONL writer) in addition to the ring.
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 *, capacity: int = 65536,
                 sink: Callable[[dict], None] | None = None):
        self.clock = clock
        self.capacity = capacity
        self.sink = sink
        self.dropped = 0
        self._lock = threading.Lock()
        self._done: collections.deque[Span] = collections.deque(
            maxlen=capacity)
        self._span_ids = itertools.count(1)
        self._trace_ids = itertools.count(1)
        self._tls = threading.local()

    # ------------------------------------------------------------- identity
    def new_trace(self) -> int:
        return next(self._trace_ids)

    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = []
            self._tls.stack = st
        return st

    def current(self) -> Span | None:
        """The innermost live span on THIS thread (ambient parent)."""
        st = getattr(self._tls, "stack", None)
        return st[-1] if st else None

    # ------------------------------------------------------------ recording
    def start(self, name: str, *, trace_id: int | None = None,
              parent=None, t0: float | None = None, **attrs) -> Span:
        """Open a live span.  Parent resolution: explicit ``parent=``
        (Span or ``(trace, span)`` tuple) wins, else the thread's current
        span, else the span is a root of ``trace_id`` (fresh trace when
        that is None too).  Does NOT push onto the thread stack — use
        :meth:`span` for ambient nesting."""
        ctx = _ctx_of(parent)
        if ctx is None:
            cur = self.current()
            if cur is not None:
                ctx = cur.context
        if ctx is not None:
            tid = trace_id if trace_id is not None else ctx[0]
            pid = ctx[1]
        else:
            tid = trace_id if trace_id is not None else self.new_trace()
            pid = 0
        return Span(name, tid, next(self._span_ids), pid,
                    self.clock() if t0 is None else t0, attrs)

    def end(self, span: Span, t1: float | None = None, **attrs) -> Span:
        """Close a live span and record it (idempotence is the caller's
        business; spans are recorded exactly when ended)."""
        span.t1 = self.clock() if t1 is None else t1
        if attrs:
            span.attrs.update(attrs)
        self._record(span)
        return span

    def record(self, name: str, *, trace_id: int | None = None,
               parent=None, t0: float, t1: float, **attrs) -> Span:
        """Record a pre-timed span in one call (sites that measured the
        interval themselves, e.g. admission)."""
        span = self.start(name, trace_id=trace_id, parent=parent, t0=t0,
                          **attrs)
        return self.end(span, t1=t1)

    def event(self, name: str, *, parent=None, **attrs) -> Span:
        """A zero-duration point event (injected faults use this): lands
        in the trace parented to the current/explicit span, so the trace
        shows exactly which operation the event interrupted."""
        t = self.clock()
        return self.record(name, parent=parent, t0=t, t1=t, **attrs)

    def make(self, name: str, *, trace_id: int, parent_id: int = 0,
             t0: float, t1: float | None = None, **attrs) -> Span:
        """Build a span WITHOUT recording it — the wave-path fast lane:
        sites that already hold explicit ids/times construct spans
        directly and hand them to :meth:`record_batch` in bulk."""
        sp = Span(name, trace_id, next(self._span_ids), parent_id, t0,
                  attrs)
        sp.t1 = t1
        return sp

    def span(self, name: str, *, trace_id: int | None = None,
             parent=None, **attrs) -> "_SpanScope":
        """Context-managed span, pushed as the thread's ambient parent
        for its body (nested ``span()``/``start()`` calls chain under
        it).  Exceptions mark ``attrs["error"]`` and re-raise."""
        sp = self.start(name, trace_id=trace_id, parent=parent, **attrs)
        return _SpanScope(self, sp, self._stack())

    def record_batch(self, spans) -> None:
        """Record many finished spans under ONE ring lock — the wave
        path ends a whole batch's queue/serve spans per dispatch, and
        per-span locking there is measurable against the p50 gate."""
        sink = self.sink
        with self._lock:
            done = self._done
            cap = done.maxlen
            for sp in spans:
                if len(done) == cap:
                    self.dropped += 1
                done.append(sp)
        if sink is not None:
            for sp in spans:
                sink(sp.to_dict())

    def _record(self, span: Span) -> None:
        sink = self.sink
        with self._lock:
            if len(self._done) == self._done.maxlen:
                self.dropped += 1
            self._done.append(span)
        if sink is not None:
            sink(span.to_dict())

    # ------------------------------------------------------------- reading
    def spans(self) -> list[Span]:
        """Snapshot of the finished-span ring, oldest first."""
        with self._lock:
            return list(self._done)

    def drain(self) -> list[Span]:
        """Pop and return everything recorded so far."""
        with self._lock:
            out = list(self._done)
            self._done.clear()
            return out

    def __len__(self) -> int:
        with self._lock:
            return len(self._done)


class _SpanScope:
    """Plain-class span context manager (a generator-based
    ``@contextmanager`` costs several µs per use — too hot for the
    per-bucket dispatch path)."""

    __slots__ = ("_tracer", "_span", "_stack")

    def __init__(self, tracer: Tracer, span: Span, stack: list):
        self._tracer = tracer
        self._span = span
        self._stack = stack

    def __enter__(self) -> Span:
        self._stack.append(self._span)
        return self._span

    def __exit__(self, exc_type, exc, tb) -> bool:
        self._stack.pop()
        if exc is not None:
            self._span.attrs["error"] = repr(exc)
        self._tracer.end(self._span)
        return False


# ------------------------------------------------------- module-level seam
#: the installed tracer (None = tracing disabled).  Hot paths read this
#: ONCE into a local and branch on ``is None`` — the seam idiom.
TRACER: Tracer | None = None


def install(tracer: Tracer) -> Tracer:
    """Enable tracing process-wide.  Mirrors ``fault.seam`` ownership:
    installing over a DIFFERENT live tracer raises (two harnesses must
    not silently interleave their spans)."""
    global TRACER
    if TRACER is not None and TRACER is not tracer:
        raise RuntimeError("a tracer is already installed")
    TRACER = tracer
    return tracer


def uninstall(tracer: Tracer | None = None) -> None:
    """Disable tracing (idempotent; passing the tracer asserts
    ownership, like ``seam.uninstall``)."""
    global TRACER
    if tracer is not None and TRACER is not None and TRACER is not tracer:
        raise RuntimeError("refusing to uninstall another tracer")
    TRACER = None


def current_context() -> tuple[int, int] | None:
    """The calling thread's ambient span context, or None when tracing
    is off / no span is live — what a cross-thread handoff captures at
    enqueue time (the maintenance executor does exactly this)."""
    tr = TRACER
    if tr is None:
        return None
    cur = tr.current()
    return None if cur is None else cur.context


class _NullSpan:
    """Reentrant no-op context manager: ``maybe_span`` returns this one
    shared instance when tracing is off (stateless, so sharing is safe)."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()


def maybe_span(name: str, *, parent=None, **attrs):
    """One-call guarded span for non-hot sites (store maintenance, spill
    phases): the disabled path is this function's single global check
    plus returning a shared no-op object."""
    tr = TRACER
    if tr is None:
        return _NULL
    return tr.span(name, parent=parent, **attrs)
