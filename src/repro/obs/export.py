"""Exporters: JSONL traces, Prometheus text exposition, bench snapshots.

Three consumers, three formats:

  * **CI artifacts** want line-delimited JSON — :func:`write_jsonl`
    dumps a tracer's finished spans one object per line, so a failed
    chaos run's artifact can be grepped or loaded incrementally.
  * **Scrapers** want Prometheus text exposition — :func:`prometheus_text`
    walks a :class:`~repro.obs.metrics.Registry` tree (counters/gauges as
    single samples, histograms as cumulative ``_bucket``/``_sum``/
    ``_count`` series, reservoirs as quantile gauges).
  * **Benchmarks** want one call — :func:`bench_snapshot` writes a
    service's trace + metrics + energy ledger to ``results/obs/`` and
    returns the paths, which is all ``benchmarks/run.py`` needs to turn
    a traced phase into uploadable artifacts.
"""
from __future__ import annotations

import json
import os
import re
from typing import Iterable

from repro.obs import trace as _trace
from repro.obs.metrics import (Counter, Gauge, Histogram, Registry,
                               Reservoir)

__all__ = ["write_jsonl", "prometheus_text", "bench_snapshot"]

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _sanitize(name: str) -> str:
    """Prometheus metric names: [a-zA-Z_:][a-zA-Z0-9_:]*."""
    name = _NAME_RE.sub("_", name)
    if name and name[0].isdigit():
        name = "_" + name
    return name


def write_jsonl(spans: Iterable, path: str | os.PathLike) -> int:
    """Write spans (Span objects or pre-rendered dicts) as JSONL;
    returns the line count.  Creates parent directories."""
    path = os.fspath(path)
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    n = 0
    with open(path, "w", encoding="utf-8") as f:
        for sp in spans:
            d = sp.to_dict() if isinstance(sp, _trace.Span) else sp
            f.write(json.dumps(d, default=str) + "\n")
            n += 1
    return n


def _fmt(v: float) -> str:
    return repr(float(v))


def prometheus_text(registry: Registry, *, prefix: str = "repro") -> str:
    """Render a registry tree (children included) in Prometheus text
    exposition format, every name prefixed with ``<prefix>_``."""
    lines: list[str] = []
    for full, m in registry.collect(prefix):
        name = _sanitize(full)
        if isinstance(m, Counter):
            lines.append(f"# TYPE {name} counter")
            lines.append(f"{name} {m.value}")
        elif isinstance(m, Gauge):
            lines.append(f"# TYPE {name} gauge")
            lines.append(f"{name} {_fmt(m.value)}")
        elif isinstance(m, Histogram):
            snap = m.snapshot()
            lines.append(f"# TYPE {name} histogram")
            cum = 0
            for edge, c in snap["buckets"]:
                cum += c
                lines.append(f'{name}_bucket{{le="{_fmt(edge)}"}} {cum}')
            cum += snap["overflow"]
            lines.append(f'{name}_bucket{{le="+Inf"}} {cum}')
            lines.append(f"{name}_sum {_fmt(snap['sum'])}")
            lines.append(f"{name}_count {snap['count']}")
        elif isinstance(m, Reservoir):
            snap = m.snapshot()
            lines.append(f"# TYPE {name} summary")
            lines.append(f'{name}{{quantile="0.5"}} {_fmt(snap["p50"])}')
            lines.append(f'{name}{{quantile="0.99"}} {_fmt(snap["p99"])}')
            lines.append(f"{name}_sum {_fmt(snap['sum'])}")
            lines.append(f"{name}_count {snap['count']}")
    return "\n".join(lines) + "\n"


def bench_snapshot(service, out_dir: str | os.PathLike,
                   name: str) -> dict:
    """One-call bench artifact drop: the installed tracer's spans to
    ``<name>.trace.jsonl``, the service's registry to ``<name>.prom``,
    and its energy-ledger snapshot + reconciliation to
    ``<name>.energy.json``.  Returns {kind: path} for what was written
    (trace omitted when no tracer is installed)."""
    out_dir = os.fspath(out_dir)
    os.makedirs(out_dir, exist_ok=True)
    written: dict[str, str] = {}

    tr = _trace.TRACER
    if tr is not None:
        tp = os.path.join(out_dir, f"{name}.trace.jsonl")
        write_jsonl(tr.spans(), tp)
        written["trace"] = tp

    reg = getattr(service, "registry", None)
    if reg is not None:
        pp = os.path.join(out_dir, f"{name}.prom")
        with open(pp, "w", encoding="utf-8") as f:
            f.write(prometheus_text(reg))
        written["prom"] = pp

    ledger = getattr(service, "ledger", None)
    if ledger is not None:
        ep = os.path.join(out_dir, f"{name}.energy.json")
        payload = {"snapshot": ledger.snapshot(),
                   "reconcile": ledger.reconcile()}
        with open(ep, "w", encoding="utf-8") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        written["energy"] = ep

    return written
