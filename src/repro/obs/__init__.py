"""Unified observability: span tracing, typed metrics, energy ledger.

One substrate replaces three hand-rolled telemetry dicts:

  * :mod:`repro.obs.trace` — explicit-clock span tracer behind a
    ``fault.seam``-style module global (off = one attribute check);
  * :mod:`repro.obs.metrics` — counters / gauges / histograms /
    reservoirs in composable registries (``BitmapService.metrics()``,
    ``SegmentStore.health()`` and ``BitmapDB.cache_stats()`` are views
    over these);
  * :mod:`repro.obs.energy` — per-phase joule ledger on the paper's
    operating points, attributing pJ to individual queries and indexed
    bits while reconciling exactly with ``ElasticScheduler`` totals;
  * :mod:`repro.obs.export` — JSONL traces, Prometheus text, one-call
    bench snapshots.

Symbols resolve lazily (the :mod:`repro` idiom): ``trace`` and
``metrics`` are stdlib-only and importable from the very bottom of the
stack (the fault injector, the WAL); ``energy`` pulls the jax-heavy
power model and must not ride along with them.
"""
from __future__ import annotations

import importlib

_SUBMODULES = ("trace", "metrics", "energy", "export")

_EXPORTS = {
    "Tracer": "trace", "Span": "trace", "install": "trace",
    "uninstall": "trace", "current_context": "trace",
    "maybe_span": "trace",
    "Registry": "metrics", "Counter": "metrics", "Gauge": "metrics",
    "Histogram": "metrics", "Reservoir": "metrics", "GLOBAL": "metrics",
    "EnergyLedger": "energy",
}

__all__ = sorted(_SUBMODULES) + sorted(_EXPORTS)


def __getattr__(name):
    if name in _SUBMODULES:
        return importlib.import_module(f"{__name__}.{name}")
    if name in _EXPORTS:
        mod = importlib.import_module(f"{__name__}.{_EXPORTS[name]}")
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return __all__
