"""Typed metric registry: counters, gauges, histograms, reservoirs.

Before this module the stack had three hand-rolled telemetry dicts —
``BitmapService.metrics()`` ad-hoc ints under the scheduler condvar,
``SegmentStore.health()`` plain attributes under the store lock, and
``BitmapDB.cache_stats()`` a mutable dict — each with its own locking
story and none exportable.  Now every layer registers *typed* metrics in
a :class:`Registry` and the old surfaces are views over it; one
``snapshot()``/``collect()`` walk feeds the Prometheus/JSONL exporters
(:mod:`repro.obs.export`).

Types:

  * :class:`Counter` — monotonic; ``inc``/``add`` under a leaf lock (a
    metric lock is never held while taking any other lock, so metric
    updates can happen under ANY caller lock without ordering issues).
  * :class:`Gauge` — last-write-wins level (queue depth, inflight).
  * :class:`Histogram` — fixed upper-bound buckets, cumulative on
    export (Prometheus ``le`` semantics), with quantile interpolation.
  * :class:`Reservoir` — bounded uniform sample over the metric's whole
    lifetime (Vitter's Algorithm R, deterministic seed): unlike a
    sliding window, p50/p99 computed from it stay stable over
    multi-hour runs because every sample ever observed had an equal
    chance to be in the pool; memory stays O(capacity) forever.

Registries compose: ``service.registry.attach("store", store.registry)``
grafts the store's metrics under a ``store_`` prefix so the service
exposes ONE tree.  :data:`GLOBAL` holds process-wide engine counters
(jit executor builds, wave dispatches, cost-model decisions, WAL
appends) — the engine's caches are process-global, so their meters are
too.

Stdlib-only.
"""
from __future__ import annotations

import bisect
import math
import random
import threading
from typing import Iterator, Sequence

__all__ = ["Counter", "Gauge", "Histogram", "Reservoir", "Registry",
           "GLOBAL", "LATENCY_BUCKETS_MS"]

#: default latency histogram edges (ms): log-spaced 0.05ms .. ~60s
LATENCY_BUCKETS_MS: tuple[float, ...] = tuple(
    round(0.05 * (1.5 ** i), 4) for i in range(35))


class Counter:
    """Monotonic counter.  ``.value`` is exact (lock-consistent), which
    is what lets the telemetry tests reconcile counters against futures
    actually resolved instead of asserting 'roughly'."""

    __slots__ = ("name", "help", "_v", "_lock")
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._v = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._v += n

    add = inc

    @property
    def value(self) -> int:
        with self._lock:
            return self._v

    def snapshot(self):
        return self.value

    def __repr__(self) -> str:
        return f"<Counter {self.name}={self.value}>"


class Gauge:
    """Last-write-wins level meter."""

    __slots__ = ("name", "help", "_v", "_lock")
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._v = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._v = v

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._v

    def snapshot(self):
        return self.value

    def __repr__(self) -> str:
        return f"<Gauge {self.name}={self.value}>"


class Histogram:
    """Fixed-bucket histogram (upper-bound edges, +Inf implicit).
    ``quantile(q)`` linearly interpolates inside the bucket the q-th
    observation falls in — O(buckets) memory at any observation count."""

    __slots__ = ("name", "help", "buckets", "_counts", "_count", "_sum",
                 "_lock")
    kind = "histogram"

    def __init__(self, name: str, buckets: Sequence[float],
                 help: str = ""):
        edges = tuple(sorted(float(b) for b in buckets))
        if not edges:
            raise ValueError("histogram needs at least one bucket edge")
        self.name = name
        self.help = help
        self.buckets = edges
        self._counts = [0] * (len(edges) + 1)     # last = overflow (+Inf)
        self._count = 0
        self._sum = 0.0
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        i = bisect.bisect_left(self.buckets, v)
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += v

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def quantile(self, q: float) -> float:
        """Interpolated q-quantile (q in [0, 1]); 0.0 when empty."""
        if not 0.0 <= q <= 1.0:
            raise ValueError("q must be in [0, 1]")
        with self._lock:
            total = self._count
            counts = list(self._counts)
        if total == 0:
            return 0.0
        rank = q * total
        cum = 0
        for i, c in enumerate(counts):
            if cum + c >= rank and c:
                lo = self.buckets[i - 1] if i else 0.0
                hi = (self.buckets[i] if i < len(self.buckets)
                      else self.buckets[-1])     # overflow: clamp to edge
                frac = (rank - cum) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            cum += c
        return self.buckets[-1]

    def snapshot(self) -> dict:
        with self._lock:
            counts = list(self._counts)
            return {"buckets": list(zip(self.buckets, counts[:-1])),
                    "overflow": counts[-1], "count": self._count,
                    "sum": self._sum}

    def __repr__(self) -> str:
        return f"<Histogram {self.name} n={self.count}>"


class Reservoir:
    """Bounded uniform lifetime sample (Algorithm R, seeded —
    deterministic given the observation sequence).  Until ``capacity``
    observations it holds *every* sample, so short benchmark phases get
    exact percentiles; past it, each of the N lifetime samples has
    capacity/N probability of being in the pool — percentiles track the
    whole run, not the last window."""

    __slots__ = ("name", "help", "capacity", "_pool", "_count", "_sum",
                 "_rng", "_lock")
    kind = "reservoir"

    def __init__(self, name: str, capacity: int = 8192, *, seed: int = 0,
                 help: str = ""):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.name = name
        self.help = help
        self.capacity = capacity
        self._pool: list[float] = []
        self._count = 0
        self._sum = 0.0
        self._rng = random.Random(seed)
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._count += 1
            self._sum += v
            if len(self._pool) < self.capacity:
                self._pool.append(v)
            else:
                j = self._rng.randrange(self._count)
                if j < self.capacity:
                    self._pool[j] = v

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def mean(self) -> float:
        with self._lock:
            return self._sum / self._count if self._count else 0.0

    def values(self) -> list[float]:
        with self._lock:
            return list(self._pool)

    def percentile(self, q: float) -> float:
        """q in [0, 100]; exact over the pool (exact over the lifetime
        while count <= capacity)."""
        pool = sorted(self.values())
        if not pool:
            return 0.0
        if len(pool) == 1:
            return pool[0]
        rank = (q / 100.0) * (len(pool) - 1)
        lo = math.floor(rank)
        hi = min(lo + 1, len(pool) - 1)
        return pool[lo] + (pool[hi] - pool[lo]) * (rank - lo)

    def snapshot(self) -> dict:
        with self._lock:
            n = self._count
            s = self._sum
        return {"count": n, "sum": s,
                "mean": s / n if n else 0.0,
                "p50": self.percentile(50), "p99": self.percentile(99)}

    def __repr__(self) -> str:
        return f"<Reservoir {self.name} n={self.count}>"


class Registry:
    """Get-or-create registry of typed metrics plus attached child
    registries (exposed under a prefix).  Creation is idempotent per
    (name, type); asking for an existing name with a different type
    raises — one name, one meaning."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, object] = {}
        self._children: dict[str, "Registry"] = {}

    # -------------------------------------------------------- constructors
    def _get_or_create(self, name: str, cls, *args, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if not isinstance(m, cls):
                    raise TypeError(f"metric {name!r} already registered "
                                    f"as {type(m).__name__}")
                return m
            m = cls(name, *args, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(name, Counter, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(name, Gauge, help)

    def histogram(self, name: str, buckets: Sequence[float],
                  help: str = "") -> Histogram:
        return self._get_or_create(name, Histogram, buckets, help=help)

    def reservoir(self, name: str, capacity: int = 8192, *, seed: int = 0,
                  help: str = "") -> Reservoir:
        return self._get_or_create(name, Reservoir, capacity, seed=seed,
                                   help=help)

    # ----------------------------------------------------------- structure
    def attach(self, prefix: str, child: "Registry") -> "Registry":
        """Graft ``child`` under ``prefix`` (its metrics export as
        ``<prefix>_<name>``).  Re-attaching the same registry under the
        same prefix is a no-op; a different one under a taken prefix
        raises."""
        with self._lock:
            have = self._children.get(prefix)
            if have is not None and have is not child:
                raise ValueError(f"prefix {prefix!r} already attached")
            self._children[prefix] = child
        return child

    def collect(self, prefix: str = "") -> Iterator[tuple[str, object]]:
        """Every (full_name, metric) in this registry and its children,
        depth-first.  Attachment cycles would loop — don't build them."""
        with self._lock:
            metrics = list(self._metrics.items())
            children = list(self._children.items())
        for name, m in metrics:
            yield (f"{prefix}_{name}" if prefix else name), m
        for sub, child in children:
            full = f"{prefix}_{sub}" if prefix else sub
            yield from child.collect(full)

    def snapshot(self) -> dict:
        """Flat ``{full_name: value}`` dict (histograms/reservoirs nest
        their own snapshot dicts) — the JSONL/bench artifact payload."""
        return {name: m.snapshot() for name, m in self.collect()}

    def __repr__(self) -> str:
        with self._lock:
            return (f"<Registry {len(self._metrics)} metrics, "
                    f"{len(self._children)} children>")


#: process-wide registry for the engine's global caches and counters
#: (executor builds, wave dispatches, cost-model decisions, WAL traffic).
#: Services attach it as the "engine" subtree of their own registry.
GLOBAL = Registry()
