"""Per-phase energy ledger on the paper's operating points.

The paper's claim is an *energy* claim — 162.9 pJ/cycle active at
1.2 V / 41 MHz, 10.6 uW clock-gated standby, 2.64 nW (0.31 pW/bit) with
reverse back-gate biasing at 0.4 V — and :class:`ElasticScheduler`
already turns those operating points into joule totals per tick.  What
it cannot say is *which query* the joules belong to.  The ledger closes
that gap:

  * every charge lands in exactly one **phase** — ``busy`` (device
    executing a wave, active power), ``awake_idle`` (core awake between
    waves, active power), ``standby`` (duty-cycled down, standby power
    at the configured CG/RBB point);
  * :meth:`EnergyLedger.attribute` drains the not-yet-attributed pool
    evenly over a wave's queries, so **sum(per-query pJ) +
    unattributed == total joules exactly** (the reconciliation rule
    ARCHITECTURE.md documents and the bench's ``energy_reconciled``
    flag checks);
  * :meth:`EnergyLedger.attribute_bits` rolls the same pool up to
    pJ-per-indexed-bit for the ingest side (MulticoreRuntime ticks
    arrive via :meth:`charge_report`).

Reconciling with the scheduler totals is by construction, not by
bookkeeping discipline: the ledger *owns* the
:class:`~repro.core.elastic.EnergyReport` that ``BitmapService``
exposes, and every joule enters through :meth:`charge` /
:meth:`charge_report` — there is no second path that could drift.
"""
from __future__ import annotations

import collections
import threading

from repro.core import power as power_model
from repro.core.elastic import ElasticScheduler, EnergyReport

__all__ = ["EnergyLedger", "PHASES"]

#: ledger phases, in the order snapshots report them
PHASES = ("busy", "awake_idle", "standby")


class EnergyLedger:
    """Joule accounting per phase with per-query attribution.

    ``scheduler`` supplies the operating points (its ``p_active`` /
    ``p_standby`` watts are the paper's calibrated powers); the ledger
    charges wall-clock phase durations at those powers into its own
    :attr:`report` (an :class:`EnergyReport` — hand this to the service
    as THE energy report so scheduler reconciliation is structural).
    """

    def __init__(self, scheduler: ElasticScheduler, *,
                 per_query_window: int = 65536):
        state = scheduler.state
        self._power = {"busy": scheduler.p_active,
                       "awake_idle": scheduler.p_active,
                       "standby": scheduler.p_standby}
        vbb = state.vbb_standby if state.use_rbb else 0.0
        #: the paper's operating points, resolved once for snapshots
        self.operating_points = {
            "vdd_active_v": state.vdd_active,
            "vdd_standby_v": state.vdd_standby,
            "vbb_standby_v": vbb,
            "standby_mode": "rbb" if state.use_rbb else "cg",
            "active_w": scheduler.p_active,
            "standby_w": scheduler.p_standby,
            "standby_cg_w": power_model.standby_power(state.vdd_standby,
                                                      0.0),
            "standby_rbb_w": power_model.standby_power(
                state.vdd_standby, state.vbb_standby),
        }
        self._lock = threading.Lock()
        #: the service-visible report; every charge merges into it
        self.report = EnergyReport()
        self.phase_seconds = {p: 0.0 for p in PHASES}
        self.phase_joules = {p: 0.0 for p in PHASES}
        self._unattributed = 0.0
        self._attributed = 0.0
        self._indexed_bits = 0
        self._per_query: collections.deque[tuple[int, float]] = (
            collections.deque(maxlen=per_query_window))

    # ------------------------------------------------------------- charging
    def charge(self, phase: str, dt: float) -> float:
        """Charge ``dt`` seconds spent in ``phase``; returns the joules
        added.  Negative/zero intervals are ignored (clock skew on tiny
        spans must not un-charge energy)."""
        if dt <= 0.0:
            return 0.0
        joules = self._power[phase] * dt
        rep = self.report
        with self._lock:
            self.phase_seconds[phase] += dt
            self.phase_joules[phase] += joules
            self._unattributed += joules
            if phase == "busy":
                rep.active_joules += joules
                rep.busy_core_seconds += dt
            elif phase == "awake_idle":
                rep.active_joules += joules
                rep.idle_core_seconds += dt
            else:
                rep.standby_joules += joules
                rep.idle_core_seconds += dt
        return joules

    def charge_report(self, tick: EnergyReport) -> None:
        """Absorb a scheduler-produced tick report (the ingest runtime's
        ``run_tick`` path): active joules land in ``busy``, standby in
        ``standby``, and the report totals merge exactly."""
        with self._lock:
            self.phase_seconds["busy"] += tick.busy_core_seconds
            self.phase_joules["busy"] += tick.active_joules
            self.phase_seconds["standby"] += tick.idle_core_seconds
            self.phase_joules["standby"] += tick.standby_joules
            self._unattributed += tick.total_joules
            self.report.merge(tick)

    def note_batch(self) -> None:
        with self._lock:
            self.report.batches += 1

    # ---------------------------------------------------------- attribution
    def attribute(self, trace_ids) -> list[float]:
        """Drain the unattributed pool evenly over ``trace_ids`` (one
        wave's queries); returns each query's share in **pJ**.  The split
        is exact by construction: the pool decreases by precisely the
        amount handed out, so attributed + unattributed always equals
        the report total."""
        ids = list(trace_ids)
        if not ids:
            return []
        with self._lock:
            take = self._unattributed
            self._unattributed = 0.0
            self._attributed += take
            share_pj = take / len(ids) * 1e12
            for tid in ids:
                self._per_query.append((tid if tid is not None else 0,
                                        share_pj))
        return [share_pj] * len(ids)

    def attribute_bits(self, bits: int) -> None:
        """Credit ``bits`` freshly indexed bits against the energy spent
        so far (ingest-side roll-up; pairs with :meth:`charge_report`)."""
        if bits > 0:
            with self._lock:
                self._indexed_bits += bits

    # -------------------------------------------------------------- reading
    def per_query_pj(self) -> list[tuple[int, float]]:
        """Recent ``(trace_id, pJ)`` attributions, oldest first (bounded
        by ``per_query_window``)."""
        with self._lock:
            return list(self._per_query)

    def snapshot(self, *, num_records: int = 0, num_keys: int = 0) -> dict:
        """One dict with phases, totals, and the paper-style roll-ups.
        ``num_records``/``num_keys`` size the serving-side index so
        pJ-per-indexed-bit is reportable even when ingest happened
        before the ledger existed."""
        with self._lock:
            seconds = dict(self.phase_seconds)
            joules = dict(self.phase_joules)
            unattributed = self._unattributed
            attributed = self._attributed
            n_queries = len(self._per_query)
            mean_pj = (sum(pj for _, pj in self._per_query) / n_queries
                       if n_queries else 0.0)
            bits = self._indexed_bits or num_records * num_keys
            total = self.report.total_joules
        return {
            "phase_seconds": seconds,
            "phase_joules": joules,
            "total_joules": total,
            "attributed_joules": attributed,
            "unattributed_joules": unattributed,
            "pj_per_query_mean": mean_pj,
            "pj_per_indexed_bit": (total * 1e12 / bits) if bits else 0.0,
            "indexed_bits": bits,
            "operating_points": dict(self.operating_points),
        }

    def reconcile(self, *, rel_tol: float = 1e-9) -> dict:
        """Check the two ledger invariants; returns a dict with ``ok``
        plus the compared quantities (bench artifacts embed it).

        1. phase joules sum to the report total (one path in);
        2. attributed + unattributed equals that same total (nothing
           leaks out of the per-query split).
        """
        with self._lock:
            phase_sum = sum(self.phase_joules.values())
            handed = self._attributed + self._unattributed
            total = self.report.total_joules
        tol = rel_tol * max(abs(total), 1e-30)
        ok = abs(phase_sum - total) <= tol and abs(handed - total) <= tol
        return {"ok": ok, "total_joules": total,
                "phase_joules_sum": phase_sum,
                "attributed_plus_unattributed": handed}

    def __repr__(self) -> str:
        with self._lock:
            total = self.report.total_joules
        return f"<EnergyLedger total={total:.3e}J>"
