from repro.parallel.sharding import (  # noqa: F401
    LogicalRules, constrain, logical_spec, set_rules, get_rules, DEFAULT_RULES,
)
