"""Logical-axis sharding: model code names axes, a rules table maps them to
mesh axes, and a divisibility guard drops any mapping that does not divide.

Why the guard: the production mesh is fixed at (data=16, model=16) [+pod=2],
but the assigned architectures have head counts (28, 25, 96/kv8), expert
counts (60, 40) and vocabs that are not all divisible by 16.  Rather than
hand-casing every arch, ``logical_spec`` checks divisibility per tensor and
falls back to replication on that axis — e.g. qwen2's 28 Q-heads replicate
over ``model`` while its head_dim (128) takes the TP sharding instead (see
"heads"/"head_dim" both mapping to "model": the first divisible one wins,
axes are never used twice).

Logical axes used by the model code:
  batch     -> ("pod", "data")   data parallel (pod folds into DP)
  fsdp      -> "data"            parameter/optimizer sharding (ZeRO-3)
  model/tp  -> "model"           tensor parallel (d_ff, heads, vocab, experts)
  seq       -> sequence parallel axis (activations, long-context)
"""
from __future__ import annotations

import threading
from typing import Sequence

from repro import compat  # noqa: F401  (get_abstract_mesh shim, jax 0.4.x)

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

AxisName = str | tuple[str, ...] | None

DEFAULT_RULES: dict[str, AxisName] = {
    "batch": ("pod", "data"),
    "fsdp": "data",
    "embed": None,           # d_model on activations: replicated
    "mlp": "model",          # d_ff
    "heads": "model",        # attention / ssm heads
    "head_dim": "model",     # fallback TP axis when heads don't divide
    "kv_heads": "model",
    "vocab": "model",
    "experts": "model",      # EP when divisible, else falls back
    "expert_mlp": "model",   # TP inside experts (used when EP doesn't divide)
    "seq": "data",           # sequence parallelism (activations only)
    "seq_sp": "model",       # Megatron-style SP: residual stream S over TP
    "cache_seq": None,
    "conv": None,
    "state": None,
}


class LogicalRules(threading.local):
    def __init__(self):
        self.rules = dict(DEFAULT_RULES)


_RULES = LogicalRules()


def set_rules(rules: dict[str, AxisName]) -> None:
    _RULES.rules = dict(rules)


def get_rules() -> dict[str, AxisName]:
    return _RULES.rules


def _mesh_axis_sizes() -> dict[str, int]:
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return {}
    return dict(mesh.shape)


def logical_spec(shape: Sequence[int], logical: Sequence[str | None]) -> P:
    """Map logical axis names to a PartitionSpec, enforcing divisibility and
    never assigning the same mesh axis twice (first divisible dim wins).
    Tuple rules (e.g. batch -> ("pod", "data")) keep whichever member axes
    exist in the current mesh."""
    sizes = _mesh_axis_sizes()
    used: set[str] = set()
    out: list[AxisName] = []
    for dim, name in zip(shape, logical):
        axis = _RULES.rules.get(name) if name else None
        if axis is None:
            out.append(None)
            continue
        axes = tuple(a for a in ((axis,) if isinstance(axis, str) else axis)
                     if sizes.get(a))
        n = 1
        for a in axes:
            n *= sizes[a]
        if not axes or dim % n or any(a in used for a in axes):
            out.append(None)
            continue
        used.update(axes)
        out.append(axes[0] if len(axes) == 1 else axes)
    return P(*out)


def constrain(x: jax.Array, logical: Sequence[str | None]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op outside a mesh
    context (smoke tests run unsharded on one CPU device)."""
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return x
    spec = logical_spec(x.shape, logical)
    return jax.lax.with_sharding_constraint(x, spec)


def _is_names(v) -> bool:
    return isinstance(v, tuple) and all(isinstance(e, (str, type(None))) for e in v)


def spec_tree(logical_tree, params):
    """Map a pytree of logical-name tuples (mirroring ``params``) to
    PartitionSpecs.  ``params`` may hold ShapeDtypeStructs (abstract init)."""
    return jax.tree.map(
        lambda names, p: logical_spec(p.shape, names),
        logical_tree, params, is_leaf=_is_names)
