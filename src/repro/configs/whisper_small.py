"""Whisper-small [audio]: 12L d_model=768 12H d_ff=3072 vocab=51865 —
encoder-decoder; conv/mel frontend is a stub (input_specs provides
precomputed frame embeddings).  [arXiv:2212.04356; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio", source="arXiv:2212.04356",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=51865,
    enc_dec=True, enc_layers=12, enc_frames=1500,
    mlp_act="gelu", rope="none",       # sinusoidal positions (see DESIGN.md)
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-small-smoke", family="audio", source="reduced",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512,
    enc_dec=True, enc_layers=2, enc_frames=32,
    mlp_act="gelu", rope="none",
    tie_embeddings=True,
)
