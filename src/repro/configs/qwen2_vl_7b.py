"""Qwen2-VL-7B [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — M-RoPE, dynamic resolution (vision frontend is a stub:
input_specs provides precomputed patch embeddings).  [arXiv:2409.12191; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm", source="arXiv:2409.12191",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size=152064,
    qkv_bias=True, rope="mrope", rope_theta=1e6, mrope_sections=(16, 24, 24),
    vlm=True, visual_prefix=1024,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="qwen2-vl-7b-smoke", family="vlm", source="reduced",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    qkv_bias=True, rope="mrope", rope_theta=1e6, mrope_sections=(2, 3, 3),
    vlm=True, visual_prefix=8,
    tie_embeddings=False,
)
