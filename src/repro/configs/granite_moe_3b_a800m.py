"""Granite-MoE-3B-A800M [moe]: 32L d_model=1536 24H (GQA kv=8)
d_ff_expert=512 vocab=49155, 40 routed experts top-8 (no shared experts;
top-k gate renormalized).  [hf:ibm-granite/granite-3.0-3b-a800m-base; hf]"""
from repro.models.config import ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    source="hf:ibm-granite/granite-3.0-3b-a800m-base",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8, head_dim=64,
    d_ff=0, vocab_size=49155,
    rope="rope", rope_theta=1e4,
    moe=MoESpec(num_experts=40, top_k=8, d_ff_expert=512, num_shared=0,
                router_norm=True),
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="granite-moe-smoke", family="moe", source="reduced",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=0, vocab_size=512,
    rope="rope",
    moe=MoESpec(num_experts=8, top_k=2, d_ff_expert=32, num_shared=0,
                router_norm=True),
    tie_embeddings=True,
)
