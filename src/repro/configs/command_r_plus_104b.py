"""Command-R-Plus-104B [dense]: 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000 — GQA, no-bias, parallel attn+FFN block.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    source="hf:CohereForAI/c4ai-command-r-plus",
    num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8, head_dim=128,
    d_ff=33792, vocab_size=256000,
    parallel_block=True, rope="rope", rope_theta=75e6, mlp_act="silu",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="command-r-plus-smoke", family="dense", source="reduced",
    num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
    d_ff=160, vocab_size=512,
    parallel_block=True, rope="rope", mlp_act="silu",
    tie_embeddings=True,
)
