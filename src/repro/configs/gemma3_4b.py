"""Gemma3-4B [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144 — 5:1 local:global sliding-window pattern, 128k context,
GeGLU, qk-norm.  [hf:google/gemma-3-1b-pt; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense", source="hf:google/gemma-3-4b-pt",
    num_layers=34, d_model=2560, num_heads=8, num_kv_heads=4, head_dim=256,
    d_ff=10240, vocab_size=262144,
    mlp_act="geglu", qk_norm=True,
    sliding_window=1024, global_every=6, rope="rope", rope_theta=1e6,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma3-4b-smoke", family="dense", source="reduced",
    num_layers=6, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    mlp_act="geglu", qk_norm=True,
    sliding_window=16, global_every=6, rope="rope",
    tie_embeddings=True,
)
