"""Qwen2-7B [dense]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — GQA with QKV bias.  [arXiv:2407.10671; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b", family="dense", source="arXiv:2407.10671",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size=152064,
    qkv_bias=True, rope="rope", rope_theta=1e6,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="qwen2-7b-smoke", family="dense", source="reduced",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    qkv_bias=True, rope="rope", rope_theta=1e6,
    tie_embeddings=False,
)
