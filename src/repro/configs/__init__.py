"""Architecture registry: ``get_config(arch_id)`` / ``get_smoke_config``.

One module per assigned architecture; each exposes CONFIG (exact published
geometry) and SMOKE (reduced same-family config for CPU tests)."""
from __future__ import annotations

import importlib

ARCHS = [
    "qwen2_vl_7b", "hymba_1_5b", "command_r_plus_104b", "gemma3_4b",
    "granite_20b", "qwen2_7b", "whisper_small", "mamba2_2_7b",
    "qwen2_moe_a2_7b", "granite_moe_3b_a800m",
]

def canonical(arch: str) -> str:
    """Accepts 'qwen2-moe-a2.7b', 'mamba2_2_7b', etc."""
    norm = arch.replace("-", "_").replace(".", "_")
    return norm if norm in ARCHS else arch


def get_config(arch: str):
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.CONFIG


def get_smoke_config(arch: str):
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.SMOKE


def all_configs():
    return {a: get_config(a) for a in ARCHS}
