"""Mamba2-2.7B [ssm]: 64L d_model=2560 attention-free, d_ff=0,
vocab=50280, ssm_state=128 — SSD (state-space duality).
[arXiv:2405.21060; unverified]"""
from repro.models.config import ModelConfig, SSMSpec

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm", source="arXiv:2405.21060",
    num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50280,
    block="ssm", rope="none",
    ssm=SSMSpec(d_state=128, head_dim=64, expand=2, conv_width=4, chunk=128,
                n_groups=1),
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-2.7b-smoke", family="ssm", source="reduced",
    num_layers=3, d_model=64, num_heads=0, num_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=512,
    block="ssm", rope="none",
    ssm=SSMSpec(d_state=16, head_dim=8, expand=2, conv_width=4, chunk=16,
                n_groups=1),
    tie_embeddings=True,
)
