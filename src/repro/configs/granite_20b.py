"""Granite-20B (code) [dense]: 52L d_model=6144 48H (MQA kv=1)
d_ff=24576 vocab=49152 — llama-style stack with multi-query attention.
[arXiv:2405.04324; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", family="dense", source="arXiv:2405.04324",
    num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1, head_dim=128,
    d_ff=24576, vocab_size=49152,
    mlp_act="gelu", rope="rope", rope_theta=1e4,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="granite-20b-smoke", family="dense", source="reduced",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
    d_ff=256, vocab_size=512,
    mlp_act="gelu", rope="rope",
    tie_embeddings=True,
)
