"""Hymba-1.5B [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention + Mamba heads in every
layer; sliding-window attention except 3 global layers (first/middle/last).
[arXiv:2411.13676; hf]"""
from repro.models.config import ModelConfig, SSMSpec

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid", source="arXiv:2411.13676",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32001,
    block="hybrid",
    ssm=SSMSpec(d_state=16, head_dim=64, expand=2, conv_width=4, chunk=128,
                n_groups=1),
    sliding_window=1024, rope="rope", rope_theta=1e4,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="hymba-1.5b-smoke", family="hybrid", source="reduced",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512,
    block="hybrid",
    ssm=SSMSpec(d_state=8, head_dim=8, expand=2, conv_width=4, chunk=16,
                n_groups=1),
    sliding_window=16, rope="rope",
    tie_embeddings=True,
)
