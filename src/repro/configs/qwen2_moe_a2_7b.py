"""Qwen2-MoE-A2.7B [moe]: 24L d_model=2048 16H (kv=16) d_ff_expert=1408
vocab=151936, 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from repro.models.config import ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe", source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=0, vocab_size=151936,
    qkv_bias=True, rope="rope", rope_theta=1e6,
    moe=MoESpec(num_experts=60, top_k=4, d_ff_expert=1408, num_shared=4),
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="qwen2-moe-smoke", family="moe", source="reduced",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=0, vocab_size=512,
    qkv_bias=True, rope="rope",
    moe=MoESpec(num_experts=8, top_k=2, d_ff_expert=32, num_shared=2),
    tie_embeddings=False,
)
