"""``repro`` — a production-scale bitmap-index system grown from the
paper's BIC core (see ROADMAP.md / ARCHITECTURE.md).

The documented entry point is the :mod:`repro.db` facade::

    import repro

    schema = repro.Schema([
        repro.Column.categorical("city", ["SF", "NY", "LA"]),
        repro.Column.binned("temp", edges=[-10, 0, 10, 20, 30, 45]),
    ])
    db = repro.BitmapDB(schema, path="/data/idx")   # durable session
    db.ingest(rows)
    res = db.query((repro.col("city") == "SF") &
                   repro.col("temp").between(15, 30))
    res.count, res.ids

    db2 = repro.open("/data/idx")                   # crash recovery

Lower layers stay directly importable (``repro.engine``, ``repro.store``,
``repro.core``, ...).  Symbols here resolve lazily — importing ``repro``
alone loads no jax-heavy module (the :mod:`repro.engine` idiom), so this
package ``__init__`` can never form an import cycle with them.
"""
from __future__ import annotations

import importlib

#: facade symbols re-exported at top level -> their home in repro.db
_DB_EXPORTS = ("BitmapDB", "Schema", "Column", "col", "Result", "open")

#: serving-port symbols -> their home in repro.serve.service
_SERVE_EXPORTS = ("BitmapService", "ServiceConfig")

_SUBMODULES = ("db", "engine", "store", "core", "data", "serve", "kernels",
               "checkpoint", "compat", "fault", "obs")

__all__ = sorted(_DB_EXPORTS + _SERVE_EXPORTS) + sorted(_SUBMODULES)


def __getattr__(name):
    if name in _SUBMODULES:
        return importlib.import_module(f"{__name__}.{name}")
    if name in _DB_EXPORTS:
        return getattr(importlib.import_module(f"{__name__}.db"), name)
    if name in _SERVE_EXPORTS:
        return getattr(
            importlib.import_module(f"{__name__}.serve.service"), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return __all__
