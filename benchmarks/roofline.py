"""Roofline analysis: LM dry-run artifacts + the bitmap-path calibration.

Two independent sections share this CLI:

**LM roofline** (``python benchmarks/roofline.py [out_dir]``) reads
results/dryrun/*.json (written by repro.launch.dryrun) and derives,
per (arch x shape x mesh):

  compute term    = HLO_FLOPs_corrected / (chips x 197 TFLOP/s)
  memory term     = HLO_bytes_corrected / (chips x 819 GB/s)
  collective term = collective_bytes_corrected / (chips x 50 GB/s link)

Corrections (documented, since XLA cost_analysis counts loop bodies once):
  1. Layer scan: corrected = L0 + L x (full - L0), where L0 is the
     num_layers=0 compile of the same cell.
  2. Attention chunk loops: the flash fwd (lax.map over nq q-chunks x scan
     over nk kv-chunks) and its custom-VJP bwd are counted once per layer;
     the missing (nq*nk - 1)/(nq*nk) fraction is added analytically.

All HLO quantities are PER-DEVICE (the partitioned module); MODEL_FLOPS is
global and the ratio uses HLO x num_devices.

**Bitmap roofline** (``python benchmarks/roofline.py bitmap [path]``)
measures the packed-bitmap query path on THIS host — STREAM-class copy
bandwidth plus per-backend sustained words/sec and dispatch overhead — and
persists the calibration JSON the cost model (`repro.engine.costmodel`)
loads to make ``auto`` a measured choice.  :func:`bitmap_roofline` is the
importable entry point.

Nothing LM-related imports at module load: the heavy ``repro.configs`` /
model imports happen inside the LM functions, so importing this module (or
running the bitmap section) never drags in the LM stack.
"""
from __future__ import annotations

import glob
import json
import os
import sys

PEAK_FLOPS = 197e12          # bf16 per chip (TPU v5e)
HBM_BW = 819e9               # B/s per chip
LINK_BW = 50e9               # B/s per ICI link


def _ensure_src() -> None:
    """Make ``repro`` importable when run from the repo root as a script
    (no-op when the package is already on the path)."""
    try:
        import repro  # noqa: F401
    except ImportError:
        sys.path.insert(0, os.path.join(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))), "src"))

Q_CHUNK, KV_CHUNK = 512, 1024


def _attn_blocks(S: int, qc: int, kc: int, block_skip: bool) -> int:
    nq, nk = -(-S // qc), -(-S // kc)
    if not block_skip:
        return nq * nk
    return sum(((i + 1) * qc + kc - 1) // kc for i in range(nq))


def _attn_correction(cfg, shape, num_devices: int,
                     block_skip: bool = False) -> tuple[float, float]:
    """(flops, bytes) missing per device due to attention chunk loops.

    The executed attention work is ``blocks`` chunk pairs of (qc x kc) each
    (the dense grid, or the causal-triangular subset under block_skip); the
    HLO counts one pair per loop, so the missing fraction is 1 - 1/blocks.
    """
    if cfg.block == "ssm" or shape.kind == "decode":
        return 0.0, 0.0
    S = shape.seq_len
    B = shape.global_batch
    qc = Q_CHUNK
    kc = qc if block_skip else KV_CHUNK
    blocks = _attn_blocks(S, qc, kc, block_skip)
    if blocks <= 1:
        return 0.0, 0.0
    frac = 1.0 - 1.0 / blocks
    mm = 4.0 * B * qc * kc * blocks * cfg.num_heads * cfg.head_dim
    if shape.kind == "train":
        per_layer = mm * (1 + 1) + mm * 2.5     # fwd + remat refwd + bwd(5mm)
    else:
        per_layer = mm
    flops = per_layer * cfg.num_layers * frac / num_devices
    blk_bytes = (qc * cfg.num_heads + 2 * kc * cfg.num_kv_heads
                 ) * cfg.head_dim * 2.0
    passes = 3 if shape.kind == "train" else 1
    bytes_ = (blocks * blk_bytes * B * cfg.num_layers * passes * frac
              / num_devices)
    if cfg.enc_dec:
        Se = cfg.enc_frames
        blocks_e = _attn_blocks(Se, Q_CHUNK, KV_CHUNK, False)
        if blocks_e > 1:
            mm_e = 4.0 * B * Se * Se * cfg.num_heads * cfg.head_dim
            fr_e = 1.0 - 1.0 / blocks_e
            mult = 4.5 if shape.kind == "train" else 1.0
            flops += mm_e * mult * cfg.enc_layers * fr_e / num_devices
    return flops, bytes_


def _model_flops(cfg, shape) -> float:
    """Assignment definition: 6*N*D train (N_active for MoE); serving uses
    2*N*tokens."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # one token per sequence


def _corrected(cell: dict, key: str, L: int) -> float | None:
    full = cell.get(key)
    l0 = (cell.get("l0") or {}).get(key)
    if full is None:
        return None
    if l0 is None:
        return full
    return l0 + L * (full - l0)


def _corrected_coll(cell: dict, L: int) -> float | None:
    full = (cell.get("collective_bytes") or {}).get("total")
    l0 = ((cell.get("l0") or {}).get("collective_bytes") or {}).get("total")
    if full is None:
        return None
    if l0 is None:
        return full
    return l0 + L * (full - l0)


def _lm_imports():
    """The LM-stack imports, deferred to first use (see module docstring)."""
    _ensure_src()
    from repro.configs import get_config
    from repro.launch.shapes import SHAPES
    from repro.models.model import global_flags  # noqa: F401  (flag defs)
    return get_config, SHAPES


def analyze(cell: dict) -> dict | None:
    if cell.get("status") != "ok":
        return None
    get_config, SHAPES = _lm_imports()
    cfg = get_config(cell["arch"])
    shape = SHAPES[cell["shape"]]
    nd = cell.get("num_devices", 256)
    L = cfg.num_layers
    flops = _corrected(cell, "flops", L)
    bytes_ = _corrected(cell, "bytes_accessed", L)
    coll = _corrected_coll(cell, L)
    block_skip = "block_skip" in (cell.get("variant") or "")
    af, ab = _attn_correction(cfg, shape, nd, block_skip=block_skip)
    # The L0 subtraction can slightly overshoot when the L0 graph keeps
    # fusion opportunities the full graph loses — clamp at zero.
    flops = max((flops or 0.0), 0.0) + af
    bytes_ = max((bytes_ or 0.0), 0.0) + ab

    t_c = flops / PEAK_FLOPS
    t_m = bytes_ / HBM_BW
    t_n = (coll or 0.0) / LINK_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_n),
              key=lambda kv: kv[1])[0]
    mf = _model_flops(cfg, shape)
    ratio = mf / (flops * nd) if flops else float("nan")
    frac = {"compute": t_c, "memory": t_m, "collective": t_n}
    total = max(t_c, t_m, t_n)
    roofline_frac = t_c / total if total else 0.0
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "dominant": dom, "model_flops": mf,
        "hlo_flops_global": flops * nd, "useful_ratio": ratio,
        "roofline_fraction": roofline_frac,
        "mem_gb": ((cell.get("memory") or {}).get("temp_size_in_bytes") or 0)
        / 1e9,
    }


def suggestion(r: dict) -> str:
    if r["dominant"] == "collective":
        return ("reduce resharding: gather weights once per layer "
                "(FSDP prefetch) or switch attention TP to sequence-parallel")
    if r["dominant"] == "memory":
        return ("raise arithmetic intensity: larger microbatch per device, "
                "fuse norms/rope into matmuls, bf16 moments")
    return ("compute-bound (good): shave redundant FLOPs — causal block "
            "skipping in flash, drop remat on cheap layers")


def markdown(rows: list[dict]) -> str:
    """§Roofline markdown table (single-pod cells only, per assignment)."""
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL/HLO | roofline frac | next move |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("mesh") != "16x16":
            continue
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                         f"| — | skipped: {r['skipped'][:48]} |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{100*r['roofline_fraction']:.1f}% | {suggestion(r)[:58]} |")
    return "\n".join(lines)


def main(out_dir: str = "results/dryrun") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            cell = json.load(f)
        r = analyze(cell)
        if r:
            rows.append(r)
        elif cell.get("status") == "skipped":
            rows.append({"arch": cell["arch"], "shape": cell["shape"],
                         "mesh": cell["mesh"], "skipped": cell["reason"]})
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':8s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'collect_s':>10s} {'dom':>10s} "
           f"{'useful':>7s} {'roofl%':>7s}")
    print(hdr)
    for r in rows:
        if "skipped" in r:
            print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
                  f"SKIPPED: {r['skipped'][:60]}")
            continue
        print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
              f"{r['compute_s']:10.4f} {r['memory_s']:10.4f} "
              f"{r['collective_s']:10.4f} {r['dominant']:>10s} "
              f"{r['useful_ratio']:7.2f} {100*r['roofline_fraction']:6.1f}%")
    return rows


# --------------------------------------------------------------- bitmap
def bitmap_roofline(path: str | None = None, *, num_records: int = 1 << 20,
                    num_keys: int = 256, save: bool = True) -> dict:
    """Measure the bitmap query path's roofline on this host and (by
    default) persist the calibration JSON the cost model loads.

    Returns a plain dict: the measured copy bandwidth, per-backend
    words/sec + dispatch overhead + bandwidth utilization (streamed bytes
    over copy bytes/sec), and where the calibration was written.
    Importable — ``repro.engine.costmodel`` does the measuring; this
    wrapper only formats and persists.
    """
    _ensure_src()
    from repro.engine import costmodel

    cal = costmodel.measure_calibration(num_records=num_records,
                                        num_keys=num_keys)
    out = {
        "platform": cal.platform,
        "copy_bytes_per_sec": cal.copy_bytes_per_sec,
        "backends": {
            n: {
                "words_per_sec": p.words_per_sec,
                "dispatch_overhead_s": p.dispatch_overhead_s,
                "bandwidth_utilization":
                    p.words_per_sec * 4.0 / cal.copy_bytes_per_sec,
            } for n, p in cal.profiles
        },
    }
    if save:
        where = costmodel.save_calibration(cal, path)
        costmodel.set_calibration(cal)
        out["calibration_path"] = where
    return out


def bitmap_main(path: str | None = None) -> dict:
    r = bitmap_roofline(path)
    print(f"platform: {r['platform']}")
    print(f"copy bandwidth: {r['copy_bytes_per_sec'] / 1e9:.2f} GB/s")
    print(f"{'backend':10s} {'words/s':>12s} {'overhead us':>12s} "
          f"{'bw util':>8s}")
    for n, p in sorted(r["backends"].items()):
        print(f"{n:10s} {p['words_per_sec']:12.3e} "
              f"{p['dispatch_overhead_s'] * 1e6:12.1f} "
              f"{100 * p['bandwidth_utilization']:7.1f}%")
    if "calibration_path" in r:
        print(f"calibration written to {r['calibration_path']}")
    return r


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "bitmap":
        bitmap_main(sys.argv[2] if len(sys.argv) > 2 else None)
    else:
        main(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
