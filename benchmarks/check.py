"""Benchmark-regression smoke gate (CI): the engine serving benches must be
present in BENCH_engine.json and every bit-exactness / perf-gate flag must
be true — including the backend-sweep gates (bulk-path bandwidth
utilization >= 50% of measured copy bandwidth, bulk never slower than ref,
cost-model auto within 5% of the best static backend).

Usage: python benchmarks/check.py [path/to/BENCH_engine.json]
"""
from __future__ import annotations

import json
import sys

REQUIRED = ("engine_planner_query_batched", "engine_streaming_append",
            "store_spill_recover", "db_facade_overhead",
            "serve_microbatch", "engine_backend_sweep",
            "fabric_scaling")
EXACTNESS_FLAGS = ("bitexact_vs_rebuild", "bitexact_recover", "bitexact",
                   "allclose", "facade_overhead_ok", "microbatch_ok",
                   "bulk_bw_ok", "bulk_not_slower_ok", "auto_ok",
                   "degraded_p99_ok", "trace_overhead_ok",
                   "energy_reconciled", "fabric_scaling_ok",
                   "fabric_bitexact", "fabric_chaos_ok")


def main(path: str = "BENCH_engine.json") -> int:
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        print(f"FAIL: {path} not found — did benchmarks/run.py run?")
        return 1
    failures = [f"missing bench row: {name}"
                for name in REQUIRED if name not in data]
    for name, entry in sorted(data.items()):
        derived = entry.get("derived", "")
        failures += [f"{name}: {flag}=False ({derived})"
                     for flag in EXACTNESS_FLAGS if f"{flag}=False" in derived]
    if failures:
        for f in failures:
            print(f"FAIL: {f}")
        return 1
    print(f"benchmark smoke OK ({len(data)} rows, "
          f"{len(REQUIRED)} required engine rows present)")
    return 0


if __name__ == "__main__":
    sys.exit(main(*sys.argv[1:]))
