"""Benchmark harness — one entry per paper table/figure, plus kernel
microbenchmarks and indexing throughput.  Prints ``name,us_per_call,derived``
CSV rows (derived = the figure-of-merit for that table: model error, MB/s,
pW/bit, ...).

  fig6_freq_power     — frequency & active power vs V_dd (paper Fig. 6)
  fig7_energy         — energy/cycle vs V_dd (paper Fig. 7; 162.9 pJ @ 1.2 V)
  fig8_leakage        — standby current vs V_bb (paper Fig. 8)
  table1_spb          — standby power per bit comparison (paper Table I)
  bic_create_cpu      — end-to-end BIC pipeline throughput, CPU-measured
  bic_query_cpu       — multi-dimensional query throughput
  engine_planner_query     — boolean predicate-tree query through the
                             engine planner (DNF -> fused passes,
                             jit-cached executors)
  engine_streaming_append  — incremental index append (StreamingIndexer)
                             vs a from-scratch rebuild of the same records
  kernel_*            — Pallas kernels (interpret mode) vs oracle timings
  elastic_energy      — multi-core elastic standby-power policy (Fig. 4)
  tpu_projection      — v5e roofline projection of indexing throughput
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core import power  # noqa: E402
from repro.core.elastic import ElasticScheduler, PowerState  # noqa: E402
from repro.engine import backends as engine_backends  # noqa: E402
from repro.engine import planner  # noqa: E402
from repro.engine.planner import key  # noqa: E402
from repro.engine.runtime import StreamingIndexer  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402

ROWS: list[tuple[str, float, str]] = []


def row(name: str, us: float, derived: str):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}", flush=True)


def timeit(fn, *args, reps=5, warmup=2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


# ------------------------------------------------------------- paper figures
def fig6_freq_power():
    errs = []
    for vdd, want_mhz in power.PAPER_ANCHORS["freq_mhz"].items():
        errs.append(abs(power.frequency(vdd) / 1e6 - want_mhz) / want_mhz)
    for vdd, want_mw in power.PAPER_ANCHORS["active_mw"].items():
        errs.append(abs(power.active_power(vdd) * 1e3 - want_mw) / want_mw)
    sweep = [(round(v, 2), round(power.frequency(v) / 1e6, 1),
              round(power.active_power(v) * 1e3, 2))
             for v in np.arange(0.4, 1.21, 0.1)]
    print("# fig6 sweep (Vdd, MHz, mW):", sweep)
    row("fig6_freq_power", 0.0, f"max_rel_err={max(errs):.3f}")


def fig7_energy():
    e12 = power.energy_per_cycle(1.2) * 1e12
    want = power.PAPER_ANCHORS["energy_pj_12"]
    sweep = [(round(v, 2), round(power.energy_per_cycle(v) * 1e12, 1))
             for v in np.arange(0.4, 1.21, 0.1)]
    print("# fig7 sweep (Vdd, pJ/cycle):", sweep)
    row("fig7_energy", 0.0, f"pJ@1.2V={e12:.1f} (paper {want})")


def fig8_leakage():
    i_min = power.standby_current(0.4, -2.0) * 1e9
    dec01 = power.standby_current(0.4, 0.0) / power.standby_current(0.4, -0.5)
    cross = (power.standby_current(1.2, -2.0) >
             power.standby_current(1.2, -1.5))
    for vdd in (0.4, 0.8, 1.2):
        pts = [(vbb, f"{power.standby_current(vdd, vbb)*1e9:.2f}nA")
               for vbb in (0.0, -0.5, -1.0, -1.5, -2.0)]
        print(f"# fig8 Vdd={vdd}: {pts}")
    row("fig8_leakage", 0.0,
        f"Istb_min={i_min:.1f}nA (paper 6.6) decade_per_0.5V={dec01:.1f} "
        f"gidl_crossover={cross}")


def table1_spb():
    ours = power.standby_power_per_bit() * 1e12
    print("# table1: design, tech, stb_power_uW, SPB_pW/bit")
    for r in power.TABLE_I:
        if r.name == "This work":
            stb = power.standby_power(0.4, -2.0) * 1e6
            spb = ours
        else:
            stb, spb = r.standby_power_uw, r.spb_pw_per_bit
        print(f"#   {r.name}, {r.technology}, {stb}, "
              f"{spb if spb is not None else '-'}")
    row("table1_spb", 0.0, f"ours_pw_bit={ours:.3f} (paper 0.31)")


# -------------------------------------------------------- indexing throughput
def bic_create_cpu():
    """End-to-end BIC pipeline (engine ref backend, jitted) on CPU: MB/s of
    record data indexed — comparable to the paper's §I CPU numbers
    (ParaSAIL 16-core: 108 MB/s; 60-core: 473 MB/s)."""
    n, w, m = 4096, 32, 256
    rng = np.random.default_rng(0)
    records = jnp.asarray(rng.integers(0, 256, (n, w), dtype=np.int32))
    keys = jnp.asarray(rng.integers(0, 256, (m,), dtype=np.int32))
    create = jax.jit(engine_backends.get_backend("ref").create_index)
    us = timeit(create, records, keys)
    mb = n * w / 1e6                     # 8-bit words, as in the paper
    row("bic_create_cpu", us, f"MB/s={mb / (us/1e6):.1f} n={n} m={m}")


def bic_query_cpu():
    m, nw = 256, 4096                    # 256 keys x 131072 records
    rng = np.random.default_rng(1)
    bi = jnp.asarray(rng.integers(0, 2 ** 32, (m, nw), dtype=np.uint32))

    @jax.jit
    def q(bi):
        rows = bi[jnp.asarray([2, 4, 5])]
        return ref.bitmap_query(rows, jnp.asarray([0, 0, 1]))

    us = timeit(q, bi)
    row("bic_query_cpu", us,
        f"Mrecords/s={(nw*32) / us:.0f} (3-operand query)")


# ------------------------------------------------------------ engine layer
def engine_planner_query():
    """Boolean predicate tree ((a|b) & c & ~d) through the planner: DNF
    normalization, jit-cached fused passes, tail mask + popcount."""
    m, n = 256, 131072
    rng = np.random.default_rng(5)
    bi = jnp.asarray(rng.integers(0, 2 ** 32, (m, n // 32), dtype=np.uint32))
    pred = (key(2) | key(7)) & key(4) & ~key(5)
    pl = planner.plan(pred)

    def q():
        return planner.execute(bi, pl, num_records=n, backend="ref")

    us = timeit(q, reps=5, warmup=2)
    row("engine_planner_query", us,
        f"Mrecords/s={n / us:.0f} passes={pl.num_passes} shape={pl.shape}")


def engine_streaming_append():
    """Incremental append of 512-record blocks vs from-scratch rebuild at
    the same total size (the rebuild cost grows with N; append does not)."""
    m, w, block, nblocks = 64, 16, 512, 8
    rng = np.random.default_rng(6)
    keys = jnp.asarray(rng.integers(0, 256, (m,), dtype=np.int32))
    blocks = [jnp.asarray(rng.integers(0, 256, (block, w), dtype=np.int32))
              for _ in range(nblocks)]

    def stream():
        si = StreamingIndexer(keys, backend="ref")
        for b in blocks:
            si.append(b)
        return si.index.packed

    def rebuild():
        be = engine_backends.get_backend("ref")
        return be.create_index(jnp.concatenate(blocks, axis=0), keys)

    us_s = timeit(stream, reps=3, warmup=1)
    us_r = timeit(rebuild, reps=3, warmup=1)
    ok = bool(jnp.all(stream() == rebuild()))
    mb = nblocks * block * w / 1e6
    row("engine_streaming_append", us_s,
        f"MB/s={mb / (us_s/1e6):.1f} rebuild_us={us_r:.0f} "
        f"bitexact_vs_rebuild={ok}")


# ------------------------------------------------------ kernel microbenches
def kernel_cam_match():
    rng = np.random.default_rng(2)
    records = jnp.asarray(rng.integers(0, 256, (64, 32), dtype=np.int32))
    keys = jnp.asarray(rng.integers(0, 256, (64,), dtype=np.int32))
    us = timeit(lambda: ops.cam_match(records, keys), reps=3, warmup=1)
    ok = bool(jnp.all(ops.cam_match(records, keys) ==
                      ref.cam_match(records, keys)))
    row("kernel_cam_match_interp", us, f"allclose={ok}")


def kernel_bit_transpose():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(0, 2 ** 32, (256, 8), dtype=np.uint32))
    us = timeit(lambda: ops.transpose(x), reps=3, warmup=1)
    ok = bool(jnp.all(ops.transpose(x) == ref.bit_transpose(x)))
    row("kernel_bit_transpose_interp", us, f"allclose={ok}")


def kernel_bitmap_query():
    rng = np.random.default_rng(4)
    rows = jnp.asarray(rng.integers(0, 2 ** 32, (4, 2048), dtype=np.uint32))
    inv = jnp.asarray([0, 1, 0, 0], dtype=jnp.int32)
    us = timeit(lambda: ops.query(rows, inv), reps=3, warmup=1)
    r1, c1 = ops.query(rows, inv)
    r2, c2 = ref.bitmap_query(rows, inv)
    ok = bool(jnp.all(r1 == r2)) and int(c1) == int(c2)
    row("kernel_bitmap_query_interp", us, f"allclose={ok}")


# -------------------------------------------------------------- elastic sim
def elastic_energy():
    """Paper Fig. 4 policy: 8-core system, diurnal workload; energy with
    CG-only standby vs CG+RBB standby."""
    workload = [800] * 3 + [80] * 5 + [0] * 16   # peak / off-peak / idle
    cg = ElasticScheduler(8, state=PowerState(use_rbb=False))
    rbb = ElasticScheduler(8, state=PowerState(use_rbb=True))
    e_cg = cg.run(workload, tick_seconds=3600 / 24).total_joules
    e_rbb = rbb.run(workload, tick_seconds=3600 / 24).total_joules
    row("elastic_energy", 0.0,
        f"CG_J={e_cg:.4f} CG+RBB_J={e_rbb:.6f} "
        f"standby_power_ratio={cg.p_standby / rbb.p_standby:.0f}x")


# ------------------------------------------------------------ tpu projection
def tpu_projection():
    """v5e roofline projection for the Pallas cam_match kernel: the record
    stream is HBM-bound (one compare+or per record-word x key on 8x128 VPU
    lanes), so projected indexing throughput ~= HBM bandwidth less the
    packed-output write amplification."""
    hbm = 819e9
    m = 256
    out_amp = (m / 8) / 32 / 32          # output words per input record word
    proj = hbm / (1 + out_amp) / 1e6
    row("tpu_projection_cam_match", 0.0,
        f"proj_MB/s_per_chip={proj:.0f} (paper FPGA core: 150 MB/s/core)")


ALL = [fig6_freq_power, fig7_energy, fig8_leakage, table1_spb,
       bic_create_cpu, bic_query_cpu, engine_planner_query,
       engine_streaming_append, kernel_cam_match, kernel_bit_transpose,
       kernel_bitmap_query, elastic_energy, tpu_projection]


def main() -> None:
    print("name,us_per_call,derived")
    for fn in ALL:
        fn()


if __name__ == "__main__":
    main()
