"""Benchmark harness — one entry per paper table/figure, plus kernel
microbenchmarks and indexing throughput.  Prints ``name,us_per_call,derived``
CSV rows (derived = the figure-of-merit for that table: model error, MB/s,
pW/bit, ...) and writes the same rows to ``BENCH_engine.json`` (override
with the ``BENCH_JSON`` env var) so CI can archive the perf trajectory and
gate on the bit-exactness flags (see benchmarks/check.py).

  fig6_freq_power     — frequency & active power vs V_dd (paper Fig. 6)
  fig7_energy         — energy/cycle vs V_dd (paper Fig. 7; 162.9 pJ @ 1.2 V)
  fig8_leakage        — standby current vs V_bb (paper Fig. 8)
  table1_spb          — standby power per bit comparison (paper Table I)
  bic_create_cpu      — end-to-end BIC pipeline throughput, CPU-measured
  bic_query_cpu       — multi-dimensional query throughput (via the planner,
                        i.e. the real serving path)
  engine_planner_query     — boolean predicate-tree query through the
                             engine planner (DNF -> fused passes,
                             jit-cached executors)
  engine_planner_query_batched — 1000 mixed-shape predicate trees served
                             through engine.batch (plan-shape bucketing,
                             vmapped executors) vs a sequential execute loop
  engine_streaming_append  — incremental index append (StreamingIndexer)
                             vs a from-scratch rebuild of the same records;
                             reports jitted-splice retrace behaviour and the
                             scanned append_many path
  store_spill_recover      — durable segment store: WAL-logged streaming
                             appends with periodic segment spills, simulated
                             crash, manifest+WAL recovery (bit-exact vs the
                             never-spilled index), and segment-parallel
                             query serving vs one resident buffer
  db_facade_overhead       — repro.db facade: a 1000-query mixed DSL batch
                             through BitmapDB.query_many (expression
                             lowering + plan caching + lazy results) vs the
                             raw engine.batch.execute_many path over the
                             same pre-built plans; CI gates the ratio
                             at <= 1.05x (and bit-exactness)
  serve_microbatch         — async BitmapService: 1000 mixed DSL queries
                             submitted concurrently by 8 simulated callers,
                             coalesced by the deadline-driven micro-batch
                             scheduler into bucketed dispatches, vs a
                             sequential per-query serve_step loop; reports
                             p50/p99 latency, queries/sec, coalesced batch
                             sizes, and the active-vs-standby energy split;
                             CI gates >= 3x throughput and bit-exactness
  engine_backend_sweep     — per-backend (ref / bulk / pallas-on-TPU)
                             streamed words/sec on a 1M-record mixed wave,
                             bulk-path bandwidth utilization vs measured
                             copy bandwidth, and the cost-model auto
                             choice vs the best static backend; persists
                             the calibration JSON the cost model loads;
                             CI gates bulk utilization >= 50%, bulk not
                             slower than ref, auto within 5% of best
  kernel_*            — Pallas kernels (interpret mode) vs oracle timings
  elastic_energy      — multi-core elastic standby-power policy (Fig. 4)
  tpu_projection      — v5e roofline projection of indexing throughput
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core import power  # noqa: E402
from repro.core.elastic import ElasticScheduler, PowerState  # noqa: E402
from repro.engine import backends as engine_backends  # noqa: E402
from repro.engine import batch as engine_batch  # noqa: E402
from repro.engine import planner, runtime  # noqa: E402
from repro.engine.planner import key  # noqa: E402
from repro.engine.runtime import StreamingIndexer  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402

ROWS: list[tuple[str, float, str]] = []


def row(name: str, us: float, derived: str):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}", flush=True)


def timeit(fn, *args, reps=5, warmup=2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


# ------------------------------------------------------------- paper figures
def fig6_freq_power():
    errs = []
    for vdd, want_mhz in power.PAPER_ANCHORS["freq_mhz"].items():
        errs.append(abs(power.frequency(vdd) / 1e6 - want_mhz) / want_mhz)
    for vdd, want_mw in power.PAPER_ANCHORS["active_mw"].items():
        errs.append(abs(power.active_power(vdd) * 1e3 - want_mw) / want_mw)
    sweep = [(round(v, 2), round(power.frequency(v) / 1e6, 1),
              round(power.active_power(v) * 1e3, 2))
             for v in np.arange(0.4, 1.21, 0.1)]
    print("# fig6 sweep (Vdd, MHz, mW):", sweep)
    row("fig6_freq_power", 0.0, f"max_rel_err={max(errs):.3f}")


def fig7_energy():
    e12 = power.energy_per_cycle(1.2) * 1e12
    want = power.PAPER_ANCHORS["energy_pj_12"]
    sweep = [(round(v, 2), round(power.energy_per_cycle(v) * 1e12, 1))
             for v in np.arange(0.4, 1.21, 0.1)]
    print("# fig7 sweep (Vdd, pJ/cycle):", sweep)
    row("fig7_energy", 0.0, f"pJ@1.2V={e12:.1f} (paper {want})")


def fig8_leakage():
    i_min = power.standby_current(0.4, -2.0) * 1e9
    dec01 = power.standby_current(0.4, 0.0) / power.standby_current(0.4, -0.5)
    cross = (power.standby_current(1.2, -2.0) >
             power.standby_current(1.2, -1.5))
    for vdd in (0.4, 0.8, 1.2):
        pts = [(vbb, f"{power.standby_current(vdd, vbb)*1e9:.2f}nA")
               for vbb in (0.0, -0.5, -1.0, -1.5, -2.0)]
        print(f"# fig8 Vdd={vdd}: {pts}")
    row("fig8_leakage", 0.0,
        f"Istb_min={i_min:.1f}nA (paper 6.6) decade_per_0.5V={dec01:.1f} "
        f"gidl_crossover={cross}")


def table1_spb():
    ours = power.standby_power_per_bit() * 1e12
    print("# table1: design, tech, stb_power_uW, SPB_pW/bit")
    for r in power.TABLE_I:
        if r.name == "This work":
            stb = power.standby_power(0.4, -2.0) * 1e6
            spb = ours
        else:
            stb, spb = r.standby_power_uw, r.spb_pw_per_bit
        print(f"#   {r.name}, {r.technology}, {stb}, "
              f"{spb if spb is not None else '-'}")
    row("table1_spb", 0.0, f"ours_pw_bit={ours:.3f} (paper 0.31)")


# -------------------------------------------------------- indexing throughput
def bic_create_cpu():
    """End-to-end BIC pipeline (engine ref backend, jitted) on CPU: MB/s of
    record data indexed — comparable to the paper's §I CPU numbers
    (ParaSAIL 16-core: 108 MB/s; 60-core: 473 MB/s)."""
    n, w, m = 4096, 32, 256
    rng = np.random.default_rng(0)
    records = jnp.asarray(rng.integers(0, 256, (n, w), dtype=np.int32))
    keys = jnp.asarray(rng.integers(0, 256, (m,), dtype=np.int32))
    create = jax.jit(engine_backends.get_backend("ref").create_index)
    us = timeit(create, records, keys)
    mb = n * w / 1e6                     # 8-bit words, as in the paper
    row("bic_create_cpu", us, f"MB/s={mb / (us/1e6):.1f} n={n} m={m}")


def bic_query_cpu():
    """Multi-dimensional query through the REAL serving path — the engine
    planner (plan-constant cache + jit-cached fused passes) — not a direct
    ref.bitmap_query call that would bypass what production serves."""
    m, nw = 256, 4096                    # 256 keys x 131072 records
    rng = np.random.default_rng(1)
    bi = jnp.asarray(rng.integers(0, 2 ** 32, (m, nw), dtype=np.uint32))
    pl = planner.plan(key(2) & key(4) & ~key(5))

    def q():
        return planner.execute(bi, pl, num_records=nw * 32, backend="ref")

    us = timeit(q)
    row("bic_query_cpu", us,
        f"Mrecords/s={(nw*32) / us:.0f} (3-operand query via planner)")


# ------------------------------------------------------------ engine layer
def engine_planner_query():
    """Boolean predicate tree ((a|b) & c & ~d) through the planner: DNF
    normalization, jit-cached fused passes, tail mask + popcount."""
    m, n = 256, 131072
    rng = np.random.default_rng(5)
    bi = jnp.asarray(rng.integers(0, 2 ** 32, (m, n // 32), dtype=np.uint32))
    pred = (key(2) | key(7)) & key(4) & ~key(5)
    pl = planner.plan(pred)

    def q():
        return planner.execute(bi, pl, num_records=n, backend="ref")

    us = timeit(q, reps=5, warmup=2)
    row("engine_planner_query", us,
        f"Mrecords/s={n / us:.0f} passes={pl.num_passes} shape={pl.shape}")


def _mixed_predicates(m: int, count: int, seed: int) -> list:
    """A serving-style query mix: seven plan-shape families over random
    key ids (single literals, AND chains, OR-of-AND trees, pure ORs)."""
    rng = np.random.default_rng(seed)

    def k() -> int:
        return int(rng.integers(0, m))

    preds = []
    for i in range(count):
        fam = i % 7
        if fam == 0:
            p = key(k())
        elif fam == 1:
            p = key(k()) & ~key(k())
        elif fam == 2:
            p = key(k()) & key(k()) & ~key(k())
        elif fam == 3:
            p = (key(k()) | key(k())) & key(k())
        elif fam == 4:
            p = (key(k()) | key(k())) & (key(k()) | key(k()))
        elif fam == 5:
            p = key(k()) | key(k()) | key(k())
        else:
            p = ((key(k()) & key(k()) & key(k())) |
                 (key(k()) & key(k()) & key(k())))
        preds.append(p)
    return preds


def engine_planner_query_batched():
    """1000 mixed-shape predicate trees against one index: a sequential
    planner.execute loop (one dispatch per query) vs engine.batch
    (plan-shape bucketing -> a handful of vmapped jit-cached dispatches)."""
    m, n, nq = 256, 65536, 1000
    rng = np.random.default_rng(7)
    bi = jnp.asarray(rng.integers(0, 2 ** 32, (m, n // 32), dtype=np.uint32))
    plans = [planner.plan(p) for p in _mixed_predicates(m, nq, 8)]

    def seq():
        return [planner.execute(bi, pl, num_records=n, backend="ref")
                for pl in plans]

    def bat():
        return engine_batch.execute_many(bi, plans, num_records=n,
                                         backend="ref")

    us_seq = timeit(seq, reps=2, warmup=1)
    us_bat = timeit(bat, reps=5, warmup=1)
    rows_b, counts_b = bat()
    seq_out = seq()
    rows_s = jnp.stack([r for r, _ in seq_out])
    counts_s = jnp.stack([c for _, c in seq_out])
    ok = bool(jnp.all(rows_b == rows_s)) and bool(jnp.all(counts_b == counts_s))
    shapes = {engine_batch.canonical_shape(pr)
              for pr in (engine_batch.lower(pl) for pl in plans) if pr}
    row("engine_planner_query_batched", us_bat,
        f"speedup_vs_sequential={us_seq/us_bat:.1f}x queries={nq} "
        f"buckets={len(shapes)} seq_us={us_seq:.0f} "
        f"Mqueries/s={nq / us_bat:.2f} bitexact={ok}")


def engine_streaming_append():
    """Incremental append of 512-record blocks vs from-scratch rebuild at
    the same total size (the rebuild cost grows with N; append does not).
    The shift/carry splice is jitted against a capacity buffer, so
    steady-state appends reuse one trace; append_many folds all splices in
    a single scanned dispatch."""
    m, w, block, nblocks = 64, 16, 512, 8
    rng = np.random.default_rng(6)
    keys = jnp.asarray(rng.integers(0, 256, (m,), dtype=np.int32))
    blocks = [jnp.asarray(rng.integers(0, 256, (block, w), dtype=np.int32))
              for _ in range(nblocks)]
    cap = (nblocks * block) // 32 + block // 32 + 2   # no growth retraces

    def stream():
        si = StreamingIndexer(keys, backend="ref", capacity_words=cap)
        for b in blocks:
            si.append(b)
        return si.index.packed

    def stream_batched():
        si = StreamingIndexer(keys, backend="ref", capacity_words=cap)
        si.append_many(jnp.stack(blocks))
        return si.index.packed

    def rebuild():
        be = engine_backends.get_backend("ref")
        return be.create_index(jnp.concatenate(blocks, axis=0), keys)

    us_s = timeit(stream, reps=3, warmup=1)
    us_m = timeit(stream_batched, reps=3, warmup=1)
    us_r = timeit(rebuild, reps=3, warmup=1)
    # splice retrace check: appends after the first must reuse the trace
    si = StreamingIndexer(keys, backend="ref", capacity_words=cap)
    si.append(blocks[0])
    traces_after_first = runtime.splice_cache_size()
    for b in blocks[1:]:
        si.append(b)
    retraces = runtime.splice_cache_size() - traces_after_first
    ok = (bool(jnp.all(stream() == rebuild())) and
          bool(jnp.all(stream_batched() == rebuild())))
    mb = nblocks * block * w / 1e6
    row("engine_streaming_append", us_s,
        f"MB/s={mb / (us_s/1e6):.1f} append_many_us={us_m:.0f} "
        f"rebuild_us={us_r:.0f} splice_retraces_per_block={retraces} "
        f"bitexact_vs_rebuild={ok}")


def store_spill_recover():
    """The restart scenario end to end: stream 8x512-record blocks through
    a store-attached StreamingIndexer (WAL append before every splice,
    segment spill every 3 blocks), "crash", recover from manifest + WAL,
    and serve a query batch segment-parallel — gating on bit-exactness of
    both the recovered index and the segment-parallel results."""
    import shutil
    import tempfile

    from repro.store import SegmentStore, open_index
    from repro.engine import policy as engine_policy

    m, w, block, nblocks = 64, 16, 512, 8
    rng = np.random.default_rng(11)
    keys = jnp.asarray(rng.integers(0, 256, (m,), dtype=np.int32))
    blocks = [jnp.asarray(rng.integers(0, 256, (block, w), dtype=np.int32))
              for _ in range(nblocks)]
    root = tempfile.mkdtemp(prefix="bic-store-bench-")
    try:
        def stream(dirname):
            si = StreamingIndexer(keys, backend="ref")
            si.attach_store(SegmentStore(os.path.join(root, dirname)),
                            flush_records=3 * block)   # leaves a WAL tail
            for b in blocks:
                si.append(b)
            return si

        stream("warmup")          # compile create_index + splice traces
        t0 = time.perf_counter()
        si = stream("idx")
        spill_us = (time.perf_counter() - t0) * 1e6
        want = engine_backends.get_backend("ref").create_index(
            jnp.concatenate(blocks, axis=0), keys)

        t0 = time.perf_counter()
        store = SegmentStore(os.path.join(root, "idx"))   # fresh process'
        rec = StreamingIndexer.restore(store, keys, backend="ref")
        jax.block_until_ready(rec.index.packed)
        recover_us = (time.perf_counter() - t0) * 1e6
        ok_rec = (bool(jnp.all(rec.index.packed == want))
                  and rec.num_records == nblocks * block)

        n = rec.num_records
        tail_n = n - store.durable_records
        tail = (engine_policy.extract_packed(
            rec.index.packed, store.durable_records, tail_n), tail_n)
        stored = open_index(store, tail=tail if tail_n else None)
        preds = _mixed_predicates(m, 200, 12)

        def serve_seg():
            return stored.query_many(preds, backend="ref")

        def serve_mem():
            return engine_batch.execute_many(want, preds, num_records=n,
                                             backend="ref")

        us_seg = timeit(serve_seg, reps=3, warmup=1)
        us_mem = timeit(serve_mem, reps=3, warmup=1)
        rs, cs = serve_seg()
        rm, cm = serve_mem()
        ok_q = bool(jnp.all(rs == rm)) and bool(jnp.all(cs == cm))
        wal_blocks = len(store.replay_wal())
        mb = nblocks * block * w / 1e6
        row("store_spill_recover", spill_us,
            f"spill_MB/s={mb / (spill_us/1e6):.1f} recover_us={recover_us:.0f} "
            f"segments={len(store.segments)} wal_tail_blocks={wal_blocks} "
            f"serve_seg_us={us_seg:.0f} serve_mem_us={us_mem:.0f} "
            f"bitexact_recover={ok_rec} bitexact={ok_q}")
    finally:
        shutil.rmtree(root, ignore_errors=True)


# ----------------------------------------------------------- repro.db layer
def _mixed_exprs(schema, count: int, seed: int) -> list:
    """A serving-style DSL query mix over the facade schema: the same
    seven plan-shape families as _mixed_predicates, expressed as typed
    column expressions."""
    from repro.db import col

    rng = np.random.default_rng(seed)
    names = [c.name for c in schema.columns]

    def pick():
        c = schema.columns[rng.integers(0, len(names))]
        return c.name, c.values[rng.integers(0, len(c.values))]

    exprs = []
    for i in range(count):
        fam = i % 7
        (n1, v1), (n2, v2), (n3, v3) = pick(), pick(), pick()
        if fam == 0:
            q = col(n1) == v1
        elif fam == 1:
            q = (col(n1) == v1) & ~(col(n2) == v2)
        elif fam == 2:
            q = (col(n1) == v1) & (col(n2) == v2) & ~(col(n3) == v3)
        elif fam == 3:
            q = col(n1).isin([v1, schema[n1].values[0]]) & (col(n2) == v2)
        elif fam == 4:
            q = ((col(n1) == v1) | (col(n2) == v2)) & \
                ((col(n3) == v3) | (col(n1) == schema[n1].values[-1]))
        elif fam == 5:
            q = (col(n1) == v1) | (col(n2) == v2) | (col(n3) == v3)
        else:
            q = ((col(n1) == v1) & (col(n2) == v2)) | \
                ((col(n2) == v2) & (col(n3) == v3))
        exprs.append(q)
    return exprs


def db_facade_overhead():
    """The facade tax: 1000 mixed DSL queries through BitmapDB.query_many
    vs raw engine.batch.execute_many — the CI gate holds the facade within
    1.05x of the raw path.

    In steady state the facade's _execute runs the SAME plan objects
    against the SAME cached packed array the raw call gets (the ``bitexact``
    flag re-verifies that per run), so its only extra wall time is the
    submission path: expression -> plan cache probes + the lazy
    ResultBatch.  That submission cost is pure Python and timed precisely
    in isolation; the primary gated ratio is ``(raw + submission) / raw``,
    which a noisy shared CI runner cannot smear the way re-timing
    ~identical 25 ms device dispatches twice can.  The directly measured
    end-to-end facade/raw ratio is additionally held under a loose 1.5x
    backstop — wide enough for shared-runner noise on identical work,
    tight enough to catch a gross execution-side facade regression (e.g.
    losing plan or packed-view reuse)."""
    from repro.db import BitmapDB, Column, Schema

    n, nq = 131072, 1000
    schema = Schema([Column.categorical(c, list(range(64)))
                     for c in ("a", "b", "c", "d")])       # 256 key rows
    rng = np.random.default_rng(13)
    enc = np.stack([rng.integers(64 * j, 64 * (j + 1), n, dtype=np.int32)
                    for j in range(4)], axis=1)
    db = BitmapDB(schema, backend="ref")
    db.append_encoded(enc)
    exprs = _mixed_exprs(schema, nq, seed=14)
    plans = [db._plan_for(q) for q in exprs]    # shared pre-built plans
    packed, nrec = db.index.packed, db.num_records

    def facade():
        return db.query_many(exprs).materialize()   # rows+counts, whole batch

    def raw():
        return engine_batch.execute_many(packed, plans, num_records=nrec,
                                         backend="ref")

    def timed(fn):
        t0 = time.perf_counter()
        jax.block_until_ready(fn()[0])
        return time.perf_counter() - t0

    jax.block_until_ready(facade()[0])          # warm compile caches
    jax.block_until_ready(raw()[0])
    us_r = min(timed(raw) for _ in range(7)) * 1e6
    us_f = min(timed(facade) for _ in range(7)) * 1e6
    t0 = time.perf_counter()
    reps = 20
    for _ in range(reps):
        db.query_many(exprs)                    # submission only, no exec
    us_submit = (time.perf_counter() - t0) / reps * 1e6
    fr, fc = facade()
    rr, rc = raw()
    ok = bool(jnp.all(fr == rr)) and bool(jnp.all(fc == rc))
    ratio = (us_r + us_submit) / us_r
    e2e = us_f / us_r
    gate = ratio <= 1.05 and e2e <= 1.5
    row("db_facade_overhead", us_f,
        f"ratio_vs_raw={ratio:.3f}x e2e_ratio={e2e:.3f}x "
        f"submit_us={us_submit:.0f} raw_us={us_r:.0f} facade_us={us_f:.0f} "
        f"queries={nq} facade_overhead_ok={gate} bitexact={ok}")


def serve_microbatch():
    """The serving-port duty cycle end to end: 1000 mixed DSL queries from
    8 concurrent caller threads through a BitmapService — submissions
    coalesce inside the delay window into a handful of vmapped bucketed
    dispatches — vs a sequential per-query serve_step loop (one dispatch
    per query, what every caller did before the service existed).  After
    the burst the service drops into standby and the meter splits joules
    into active vs standby (the paper's CG+RBB model).  CI gates the
    speedup at >= 3x with bit-identical results."""
    import threading

    from repro.db import BitmapDB, Column, Schema
    from repro.serve.step import make_bitmap_query_step

    n, nq, callers = 131072, 1000, 8
    schema = Schema([Column.categorical(c, list(range(64)))
                     for c in ("a", "b", "c", "d")])       # 256 key rows
    rng = np.random.default_rng(21)
    enc = np.stack([rng.integers(64 * j, 64 * (j + 1), n, dtype=np.int32)
                    for j in range(4)], axis=1)
    db = BitmapDB(schema, backend="ref")
    db.append_encoded(enc)
    exprs = _mixed_exprs(schema, nq, seed=22)

    step = make_bitmap_query_step(db)
    step(exprs)                        # warm full-batch traces
    for q in exprs[:14]:
        step([q])                      # warm the Q=1 per-family traces
    t0 = time.perf_counter()
    seq = [step([q]) for q in exprs]   # the pre-service serving loop
    seq_s = time.perf_counter() - t0
    step.service.close()

    def storm(svc):
        futs = [None] * nq

        def caller(lane: int) -> None:
            for i in range(lane, nq, callers):
                futs[i] = svc.submit(exprs[i])

        threads = [threading.Thread(target=caller, args=(c,))
                   for c in range(callers)]
        t0 = time.perf_counter()
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        svc.drain()
        return futs, time.perf_counter() - t0

    svc_kw = dict(max_batch=256, max_delay_ms=2.0, idle_after_ms=20.0)
    warm = db.serve(**svc_kw)
    # compile every (bucket shape x power-of-two size) the scheduler can
    # emit — coalesced batch compositions are thread-timing dependent, so
    # a first-sight size mid-measurement would be a compile, not serving
    warm.warmup(exprs)
    for s in (32, 64, 128, 256):       # mixed-composition re-assembly
        for off in (0, 77, 211):       # shapes at several size brackets
            db.query_many(exprs[off:off + s], pad_output=True).materialize()
    storm(warm)                        # warm the threaded path end to end
    storm(warm)                        # (twice: two batch compositions)
    warm.close()
    svc = db.serve(**svc_kw)
    # steady-state figure: best of two storms (same min-of-reps
    # convention as timeit above — a residual first-sight composition
    # compile in storm one is warmup, not serving throughput)
    futs, s1 = storm(svc)
    futs, s2 = storm(svc)
    svc_s = min(s1, s2)
    deadline = time.time() + 5         # idle out into standby
    while svc.state != "standby" and time.time() < deadline:
        time.sleep(0.005)
    m = svc.metrics()
    ok = True
    for f, (r, c) in zip(futs, seq):
        rr, cc = f.result()
        ok = ok and bool(jnp.all(rr == r[0])) and int(cc) == int(c[0])
    svc.close()
    speedup = seq_s / svc_s
    gate = speedup >= 3.0

    # tracing-enabled storm: the same workload with the repro.obs span
    # tracer installed — gates the observability tax (traced p50 within
    # 1.05x of untraced, plus timer-noise slack) and that the energy
    # ledger's per-query pJ attribution reconciles with the scheduler
    # totals; writes the JSONL trace + Prometheus snapshot CI archives
    from repro.obs import export as obs_export
    from repro.obs import trace as obs_trace
    tracer = obs_trace.Tracer(capacity=1 << 18)
    obs_trace.install(tracer)
    try:
        svc_t = db.serve(**svc_kw)
        storm(svc_t)                   # warm the traced path
        futs_t, t1 = storm(svc_t)
        futs_t, t2 = storm(svc_t)
        trc_s = min(t1, t2)
        mt = svc_t.metrics()
        t_ok = True
        for f, (r, c) in zip(futs_t, seq):
            rr, cc = f.result()
            t_ok = t_ok and bool(jnp.all(rr == r[0])) and int(cc) == int(c[0])
        rec = svc_t.ledger.reconcile()
        pq = svc_t.ledger.per_query_pj()
        out_dir = os.path.join(
            os.path.dirname(os.environ.get("BENCH_JSON", "")) or ".",
            "results", "obs")
        paths = obs_export.bench_snapshot(svc_t, out_dir, "serve_microbatch")
        svc_t.close()
    finally:
        obs_trace.uninstall(tracer)
    reconciled = bool(rec["ok"]) and t_ok and len(pq) > 0

    # the overhead gate pairs per-query p50 on ONE service, tracing
    # toggled between phases: the storm above runs at saturation, where
    # its 2x run-to-run wall-time variance (thread-timing-dependent wave
    # composition) would drown a 5% latency bound — paired single-query
    # latencies through the same live scheduler measure the actual
    # per-query tracing tax instead
    svc_o = db.serve(**svc_kw)

    def p50_sample(k):
        lats = []
        for i in range(k):
            t0 = time.perf_counter()
            svc_o.submit(exprs[i % nq]).result()
            lats.append(time.perf_counter() - t0)
        return float(np.percentile(np.asarray(lats) * 1e3, 50))

    p50_sample(50)                     # warm this service's shapes
    p50_base = p50_sample(200)
    tracer_o = obs_trace.Tracer(capacity=1 << 18)
    obs_trace.install(tracer_o)
    try:
        p50_traced = p50_sample(200)
    finally:
        obs_trace.uninstall(tracer_o)
    svc_o.close()
    # absolute slack floors the gate against sub-ms timer noise
    trace_ok = p50_traced <= 1.05 * p50_base + 0.1

    # degraded-mode storm: a seeded schedule of transient dispatch faults
    # (roughly every 3rd wave) hits the same workload — the self-healing
    # retry path must hold p99 within 5x of the clean run's p99 while
    # staying bit-identical (ISSUE: degraded-mode latency budget)
    from repro.fault import FaultInjector, FaultPlan, FaultSpec
    plan = FaultPlan(tuple(
        FaultSpec("engine.dispatch", "dispatch_error", occurrence=o)
        for o in range(1, 240, 3)))
    svc_d = db.serve(retry_base_ms=0.5, **svc_kw)
    with FaultInjector(plan) as inj:
        storm(svc_d)                   # both storms run under fault load
        futs_d, _ = storm(svc_d)
    md = svc_d.metrics()
    d_ok = bool(inj.fired("engine.dispatch"))   # vacuous unless faults hit
    for f, (r, c) in zip(futs_d, seq):
        rr, cc = f.result()
        d_ok = d_ok and bool(jnp.all(rr == r[0])) and int(cc) == int(c[0])
    retries = md.health["wave_retries"]
    svc_d.close()
    d_gate = d_ok and md.latency_p99_ms <= 5.0 * m.latency_p99_ms

    row("serve_microbatch", svc_s * 1e6,
        f"speedup_vs_sequential_step={speedup:.1f}x queries={nq} "
        f"callers={callers} qps={nq / svc_s:.0f} "
        f"p50_ms={m.latency_p50_ms:.2f} p99_ms={m.latency_p99_ms:.2f} "
        f"batch_mean={m.batch_mean:.0f} batch_max={m.batch_max} "
        f"batches={m.batches} state={m.state} "
        f"active_J={m.active_joules:.2e} standby_J={m.standby_joules:.2e} "
        f"degraded_p99_ms={md.latency_p99_ms:.2f} wave_retries={retries} "
        f"faults_fired={len(inj.events)} "
        f"traced_p50_ms={p50_traced:.2f} untraced_p50_ms={p50_base:.2f} "
        f"traced_storm_p50_ms={mt.latency_p50_ms:.2f} "
        f"traced_spans={len(tracer)} trace_qps={nq / trc_s:.0f} "
        f"pj_per_query={mt.energy['pj_per_query_mean']:.3e} "
        f"microbatch_ok={gate} bitexact={ok} degraded_p99_ok={d_gate} "
        f"trace_overhead_ok={trace_ok} energy_reconciled={reconciled}")


def engine_backend_sweep():
    """The bulk-bitwise backend sweep at bandwidth-bound size: 64 mixed
    plans over a 256-key x 1M-record index, per candidate backend, with
    the measured numbers persisted as the cost model's calibration (the
    CI artifact) and then ``auto`` timed against the best static choice.

    Derived figures: per-backend streamed words/sec, the bulk path's
    bandwidth utilization vs a STREAM-class copy measured with the same
    machinery (gated >= 50% in check.py), bulk never slower than ref
    (within a 15% noise band), and auto within 5% of the best static
    backend — the cost model reuses the exact jit-cached executor the
    static run compiled, so only the decision overhead separates them."""
    from repro.engine import costmodel

    n, m, nq = 1 << 20, 256, 64
    nw = n // 32
    rng = np.random.default_rng(31)
    bi = jnp.asarray(rng.integers(0, 2 ** 32, (m, nw), dtype=np.uint32))
    plans = [planner.plan(p) for p in _mixed_predicates(m, nq, 32)]
    tiny = jnp.asarray(rng.integers(0, 2 ** 32, (m, 16), dtype=np.uint32))

    # Interleaved reps: one round-robin over every candidate per rep, so
    # machine-load drift between phases (the killer on shared single-core
    # runners) hits all candidates equally instead of whichever was timed
    # last.  Returns ALL rep times: throughput figures take the per-name
    # min, while the perf gates compare candidates via the per-rep PAIRED
    # ratio (adjacent calls in one rep share machine state, so its min
    # over reps cancels the rep-scale drift that per-name mins cannot).
    def interleaved(fns: dict, reps: int = 7, warmup: int = 2) -> dict:
        for fn in fns.values():
            for _ in range(warmup):
                jax.block_until_ready(fn())
        times = {k: [] for k in fns}
        for _ in range(reps):
            for k, fn in fns.items():
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                times[k].append(time.perf_counter() - t0)
        return times                         # seconds per label, per rep

    def paired_ratio(times: dict, name: str, others: tuple) -> float:
        """Min over reps of name's time vs the best other IN THE SAME
        rep — the drift-cancelling "never slower" statistic."""
        return min(ts / min(times[o][i] for o in others)
                   for i, ts in enumerate(times[name]))

    copy = jax.jit(lambda a: a | jnp.uint32(0))

    def run(name):
        return engine_batch.execute_many(bi, plans, num_records=n,
                                         backend=name)

    # streamed words of this wave (padded bucket shapes x index words)
    shapes, _, _ = costmodel._bucket_shapes(plans)
    words = costmodel._streamed_words(shapes, nw)

    names = costmodel.candidates()
    stage1 = {name: (lambda name=name: run(name)) for name in names}
    stage1["copy"] = lambda: copy(bi)
    t_static = interleaved(stage1)
    copy_bps = 2.0 * bi.nbytes / min(t_static.pop("copy"))
    outs = {name: run(name) for name in names}
    ok = all(bool(jnp.all(outs[name][0] == outs["ref"][0]))
             and bool(jnp.all(outs[name][1] == outs["ref"][1]))
             for name in names)

    profiles = []
    for name in names:
        t_tiny = interleaved({name: lambda name=name:
                              engine_batch.execute_many(
                                  tiny, plans[:1], num_records=512,
                                  backend=name)}, reps=3, warmup=1)[name]
        profiles.append((name, costmodel.BackendProfile(
            words / min(t_static[name]), max(min(t_tiny), 1e-7))))

    # the sweep IS the calibration measurement: persist it so the cost
    # model's auto choice provably tracks what this host just measured
    cal = costmodel.Calibration(tuple(sorted(profiles)), copy_bps,
                                jax.default_backend(), "measured")
    cal_path = costmodel.save_calibration(cal)
    costmodel.set_calibration(cal)

    # auto vs the statics, same interleaved protocol — auto reuses the
    # winner's jit-cached executor, so only decision overhead separates
    stage2 = {name: (lambda name=name: run(name)) for name in names}
    stage2["auto"] = lambda: run("auto")
    t2 = interleaved(stage2)
    ra, ca = run("auto")
    ok = ok and bool(jnp.all(ra == outs["ref"][0])) \
        and bool(jnp.all(ca == outs["ref"][1]))

    chosen = costmodel.decide(plans, num_words=nw, num_keys=m).backend
    # per-name best across BOTH interleaved stages (14 samples each):
    # drift only ever inflates a sample, so the combined min is the
    # fairest per-backend throughput figure
    t_best = {name: min(t_static[name] + t2[name]) for name in names}
    us_auto = min(t2["auto"]) * 1e6
    util = (words / t_best["bulk"]) * 4.0 / copy_bps
    bulk_bw_ok = util >= 0.5
    # "never slower" gates use the PAIRED per-rep ratio: bulk vs ref in
    # the same round-robin rep (both stages contribute reps), and auto —
    # measured only in stage 2 — vs the stage-2 statics.  Auto reuses the
    # chosen backend's jit-cached executor, so only the (memoized)
    # decision overhead separates them; the 5% margin absorbs what per-
    # rep pairing cannot cancel on a shared single-core runner.
    both = {name: t_static[name] + t2[name] for name in names}
    bulk_vs_ref = paired_ratio(both, "bulk", ("ref",))
    bulk_not_slower_ok = bulk_vs_ref <= 1.15
    auto_ratio = paired_ratio(t2, "auto", tuple(names))
    auto_ok = auto_ratio <= 1.05 or paired_ratio(t2, "auto",
                                                 (chosen,)) <= 1.03
    wps = " ".join(f"{name}_Mwords/s={words / t_best[name] / 1e6:.0f}"
                   for name in names)
    row("engine_backend_sweep", us_auto,
        f"{wps} copy_GB/s={copy_bps / 1e9:.2f} "
        f"bulk_bw_util={util:.2f} bulk_vs_ref={bulk_vs_ref:.3f}x "
        f"auto_vs_best={auto_ratio:.3f}x queries={nq} records={n} "
        f"calibration={cal_path} bulk_bw_ok={bulk_bw_ok} "
        f"bulk_not_slower_ok={bulk_not_slower_ok} auto_ok={auto_ok} "
        f"bitexact={ok}")


# ------------------------------------------------------ kernel microbenches
def kernel_cam_match():
    rng = np.random.default_rng(2)
    records = jnp.asarray(rng.integers(0, 256, (64, 32), dtype=np.int32))
    keys = jnp.asarray(rng.integers(0, 256, (64,), dtype=np.int32))
    us = timeit(lambda: ops.cam_match(records, keys), reps=3, warmup=1)
    ok = bool(jnp.all(ops.cam_match(records, keys) ==
                      ref.cam_match(records, keys)))
    row("kernel_cam_match_interp", us, f"allclose={ok}")


def kernel_bit_transpose():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(0, 2 ** 32, (256, 8), dtype=np.uint32))
    us = timeit(lambda: ops.transpose(x), reps=3, warmup=1)
    ok = bool(jnp.all(ops.transpose(x) == ref.bit_transpose(x)))
    row("kernel_bit_transpose_interp", us, f"allclose={ok}")


def kernel_bitmap_query():
    rng = np.random.default_rng(4)
    rows = jnp.asarray(rng.integers(0, 2 ** 32, (4, 2048), dtype=np.uint32))
    inv = jnp.asarray([0, 1, 0, 0], dtype=jnp.int32)
    us = timeit(lambda: ops.query(rows, inv), reps=3, warmup=1)
    r1, c1 = ops.query(rows, inv)
    r2, c2 = ref.bitmap_query(rows, inv)
    ok = bool(jnp.all(r1 == r2)) and int(c1) == int(c2)
    row("kernel_bitmap_query_interp", us, f"allclose={ok}")


# -------------------------------------------------------------- elastic sim
def elastic_energy():
    """Paper Fig. 4 policy: 8-core system, diurnal workload; energy with
    CG-only standby vs CG+RBB standby."""
    workload = [800] * 3 + [80] * 5 + [0] * 16   # peak / off-peak / idle
    cg = ElasticScheduler(8, state=PowerState(use_rbb=False))
    rbb = ElasticScheduler(8, state=PowerState(use_rbb=True))
    e_cg = cg.run(workload, tick_seconds=3600 / 24).total_joules
    e_rbb = rbb.run(workload, tick_seconds=3600 / 24).total_joules
    row("elastic_energy", 0.0,
        f"CG_J={e_cg:.4f} CG+RBB_J={e_rbb:.6f} "
        f"standby_power_ratio={cg.p_standby / rbb.p_standby:.0f}x")


# ------------------------------------------------------------ tpu projection
def tpu_projection():
    """v5e roofline projection for the Pallas cam_match kernel: the record
    stream is HBM-bound (one compare+or per record-word x key on 8x128 VPU
    lanes), so projected indexing throughput ~= HBM bandwidth less the
    packed-output write amplification."""
    hbm = 819e9
    m = 256
    out_amp = (m / 8) / 32 / 32          # output words per input record word
    proj = hbm / (1 + out_amp) / 1e6
    row("tpu_projection_cam_match", 0.0,
        f"proj_MB/s_per_chip={proj:.0f} (paper FPGA core: 150 MB/s/core)")


ALL = [fig6_freq_power, fig7_energy, fig8_leakage, table1_spb,
       bic_create_cpu, bic_query_cpu, engine_planner_query,
       engine_planner_query_batched, engine_streaming_append,
       store_spill_recover, db_facade_overhead, serve_microbatch,
       engine_backend_sweep,
       kernel_cam_match, kernel_bit_transpose, kernel_bitmap_query,
       elastic_energy, tpu_projection]


def main() -> None:
    print("name,us_per_call,derived")
    for fn in ALL:
        fn()
    path = os.environ.get("BENCH_JSON", "BENCH_engine.json")
    with open(path, "w") as f:
        json.dump({name: {"us_per_call": us, "derived": derived}
                   for name, us, derived in ROWS}, f, indent=2)
        f.write("\n")
    print(f"# wrote {path} ({len(ROWS)} rows)")


if __name__ == "__main__":
    main()
