"""Fabric scaling benchmark — one query plane over N shard PROCESSES.

Measures the distributed read path end to end: ``spawn_shards`` forks
1 / 2 / 4 / 8 real worker processes (each a ``BitmapDB`` +
``BitmapService`` + socket server over its hash-partition of the
records), a ``FabricClient`` ingests one corpus through the exactly-once
append protocol, and a 10k-query storm of owner-pruned predicates is
submitted concurrently and merged.  Three gated claims (benchmarks/
check.py):

  fabric_scaling_ok — read throughput scales: with owner pruning each
      query executes against 1/N of the records on 1 of N processes, so
      aggregate qps at N shards must reach >= 0.7x the core-aware linear
      ideal, ``qps_1 * min(N, cpu_count)``.  On a single-core runner the
      ideal is flat and the gate degenerates to "eight processes cost at
      most 30% over one" (pure fabric overhead); on a multi-core runner
      it demands real parallel speedup.  The per-size counts must also
      be identical — a scaling number over wrong answers is worthless.
  fabric_bitexact  — a mixed fan-out suite (DSL expressions + raw
      predicate trees, rows + counts + ids) through the 8-process fabric
      is bit-identical to one single-node ``BitmapDB`` session over the
      same records.
  fabric_chaos_ok  — a seeded ``network`` fault schedule (drop /
      duplicate / delay / reorder on the rpc seams) loses ZERO
      acknowledged writes: every acked append is durably applied
      (server-side ``info()`` totals) and final counts match a clean
      reference.

Writes/merges its row into BENCH_engine.json (``BENCH_JSON`` env var
overrides), preserving rows from benchmarks/run.py.

Usage: python benchmarks/fabric.py [--sizes 1,2,4,8] [--queries 10000]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.db import BitmapDB, Column, Schema, col  # noqa: E402
from repro.engine.planner import key  # noqa: E402
from repro.fabric.client import FabricClient  # noqa: E402
from repro.fabric.shardmap import ShardMap  # noqa: E402
from repro.fabric.worker import spawn_shards  # noqa: E402

CARD = 64                     # values per column -> 256 key rows
NCOLS = 4
SEED = 7


def _schema() -> Schema:
    return Schema([Column.categorical(c, list(range(CARD)))
                   for c in ("a", "b", "c", "d")])


def _records(n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.stack([rng.integers(CARD * j, CARD * (j + 1), n,
                                  dtype=np.int32)
                     for j in range(NCOLS)], axis=1)


def _pruned_queries(nq: int, seed: int) -> list:
    """Owner-pruned 3-pass predicates: the column-0 literal pins the
    owning shard, the other two keep per-query execution non-trivial."""
    rng = np.random.default_rng(seed)
    return [key(int(rng.integers(0, CARD)))
            & key(int(rng.integers(CARD, 2 * CARD)))
            & ~key(int(rng.integers(2 * CARD, 3 * CARD)))
            for _ in range(nq)]


def _fanout_queries(nq: int, seed: int) -> list:
    """Un-prunable mixed suite (DSL + raw trees): every query consults
    every shard and the client OR-splices rows back together."""
    rng = np.random.default_rng(seed)

    def v(j):
        return int(rng.integers(0, CARD))

    out = []
    for i in range(nq):
        fam = i % 5
        if fam == 0:
            out.append(col("b") == v(1))
        elif fam == 1:
            out.append(col("b").isin([v(1), v(1)]) & (col("c") == v(2)))
        elif fam == 2:
            out.append((col("c") == v(2)) | (col("d") == v(3)))
        elif fam == 3:
            out.append(key(CARD + v(1)) & ~key(2 * CARD + v(2)))
        else:
            out.append((col("a") == v(0)) | (col("b") == v(1)))
    return out


def _shardmap(num_shards: int) -> ShardMap:
    return ShardMap(num_shards=num_shards, strategy="hash",
                    column_index=0, base=0, cardinality=CARD, seed=SEED)


def _storm(fc: FabricClient, queries: list, *, count_only: bool = True):
    t0 = time.perf_counter()
    futs = fc.submit_many(queries, count_only=count_only)
    fc.drain()
    counts = [f.count for f in futs]
    return time.perf_counter() - t0, counts, futs


def fabric_scaling(sizes: tuple[int, ...], n: int, nq: int,
                   artifact_dir: str | None = None) -> dict:
    recs = _records(n, seed=3)
    storm_qs = _pruned_queries(nq, seed=77)
    ident_qs = _fanout_queries(512, seed=78)

    # single-node reference for the bit-identity phase
    ref = BitmapDB(_schema())
    ref.append_encoded(recs)
    ref_res = ref.query_many(ident_qs).materialize()
    ref_rows = np.asarray(ref_res[0])
    ref_counts = [int(c) for c in ref_res[1]]
    ref_ids = [np.flatnonzero(np.unpackbits(
        ref_rows[i].view(np.uint8), bitorder="little")[:n])
        for i in range(len(ident_qs))]
    del ref, ref_res                  # keep worker processes out of swap

    qps: dict[int, float] = {}
    counts0: list[int] | None = None
    counts_ok = True
    bitexact = False
    for num_shards in sizes:
        t0 = time.perf_counter()
        with spawn_shards(num_shards, schema=_schema(),
                          service_config={"max_batch": 512},
                          artifact_dir=(artifact_dir
                                        if num_shards == max(sizes)
                                        else None)) as fleet:
            t_spawn = time.perf_counter() - t0
            fc = FabricClient.connect(fleet.addresses,
                                      _shardmap(num_shards),
                                      schema=_schema(), max_batch=2048)
            t0 = time.perf_counter()
            for i in range(0, n, 131072):
                fc.append_encoded(recs[i:i + 131072])
            t_load = time.perf_counter() - t0
            _storm(fc, storm_qs[:2048])          # warm shapes + plans
            dt, counts, _ = _storm(fc, storm_qs)
            qps[num_shards] = nq / dt
            if counts0 is None:
                counts0 = counts
            elif counts != counts0:
                counts_ok = False
            print(f"# fabric_scaling shards={num_shards} "
                  f"spawn={t_spawn:.1f}s load={t_load:.1f}s "
                  f"storm={dt:.2f}s qps={nq / dt:.0f}", flush=True)
            if num_shards == max(sizes):
                # bit-identity: fan-out suite, rows + counts + ids
                futs = fc.submit_many(ident_qs)
                fc.drain()
                bitexact = True
                for i, f in enumerate(futs):
                    row = np.asarray(f.rows)[:ref_rows.shape[1]]
                    bitexact = (bitexact
                                and row.shape == ref_rows[i].shape
                                and bool(np.array_equal(row, ref_rows[i]))
                                and int(f.count) == ref_counts[i]
                                and bool(np.array_equal(f.ids,
                                                        ref_ids[i])))
                stats = fc.metrics()
            fc.close()

    cores = os.cpu_count() or 1
    lo, hi = min(sizes), max(sizes)
    ideal = qps[lo] * min(hi, cores)
    eff = qps[hi] / ideal
    scaling_ok = eff >= 0.7 and counts_ok
    return {"qps": qps, "eff": eff, "cores": cores,
            "scaling_ok": scaling_ok, "bitexact": bitexact,
            "counts_ok": counts_ok, "served": stats.get("served"),
            "storm_s": nq / qps[hi]}


def fabric_chaos(seed: int = 23) -> dict:
    """Loopback fabric under a seeded network fault schedule: zero
    acknowledged-write loss, final counts equal a clean reference."""
    from repro.fault import FaultInjector, FaultPlan

    m, nblk, blk = 96, 6, 64
    plan = FaultPlan.random(seed, profile="network", n_faults=16,
                            max_occurrence=24, max_stall_s=0.001)
    rng = np.random.default_rng(seed * 11 + 1)
    blocks = [rng.integers(0, m, (blk, 3)).astype(np.int32)
              for _ in range(nblk)]
    ref = BitmapDB(num_keys=m)
    for b in blocks:
        ref.append_encoded(b)
    truth = [ref.query(key(i)).count for i in range(m)]

    # schemaless session: every column shares the key range, so pruning
    # must stay off (cardinality=0); routing still hashes column 0
    sm = ShardMap(num_shards=2, strategy="hash", column_index=0,
                  base=0, cardinality=0, seed=seed)
    fc = FabricClient.local([BitmapDB(num_keys=m) for _ in range(2)], sm,
                            max_delay_ms=1.0, request_timeout_s=0.5,
                            request_retries=10, append_retries=12)
    acked = 0
    fired = 0
    try:
        with FaultInjector(plan) as inj:
            for b in blocks:
                acked = fc.append_encoded(b)   # returns the acked total
            futs = fc.submit_many([key(i) for i in range(m)],
                                  count_only=True)
            fc.drain()
            final = [f.count for f in futs]
            fired = len(inj.fired())
        stored = sum(p["num_records"] for p in fc.info())
    finally:
        fc.close()
    ok = (acked == nblk * blk and stored == acked and final == truth)
    return {"acked": acked, "stored": stored, "fired": fired,
            "counts_match": final == truth, "ok": ok}


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--sizes", default="1,2,4,8")
    ap.add_argument("--queries", type=int, default=10_000)
    ap.add_argument("--records", type=int, default=1 << 20)
    ap.add_argument("--artifacts", default=None, metavar="DIR",
                    help="write per-shard trace/health/metrics JSON for "
                         "the largest fleet (CI fabric-smoke uploads)")
    a = ap.parse_args(argv)
    sizes = tuple(int(s) for s in a.sizes.split(","))

    print("name,us_per_call,derived")
    sc = fabric_scaling(sizes, a.records, a.queries, a.artifacts)
    ch = fabric_chaos()
    qps_s = " ".join(f"qps{k}={v:.0f}" for k, v in sorted(sc["qps"].items()))
    us = sc["storm_s"] / a.queries * 1e6
    derived = (f"{qps_s} eff_vs_linear={sc['eff']:.2f} "
               f"cores={sc['cores']} shards={max(sizes)} "
               f"queries={a.queries} records={a.records} "
               f"chaos_acked={ch['acked']} chaos_stored={ch['stored']} "
               f"chaos_faults={ch['fired']} "
               f"fabric_scaling_ok={sc['scaling_ok']} "
               f"fabric_bitexact={sc['bitexact']} "
               f"fabric_chaos_ok={ch['ok']}")
    print(f"fabric_scaling,{us:.2f},{derived}", flush=True)

    path = os.environ.get("BENCH_JSON", "BENCH_engine.json")
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data["fabric_scaling"] = {"us_per_call": us, "derived": derived}
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
        f.write("\n")
    print(f"# merged fabric_scaling into {path} ({len(data)} rows)")
    return 0 if (sc["scaling_ok"] and sc["bitexact"] and ch["ok"]) else 1


if __name__ == "__main__":
    sys.exit(main())
