"""Chaos smoke driver (CI): seeded fault schedules against the full
ingest + serve + maintenance stack, clean run vs faulted run.

For each fixed seed this runs the same workload twice — once clean, once
under ``FaultPlan.random(seed, profile="all")`` — and requires:

  * bit-identical per-wave query counts (retries / fallback / repair are
    invisible in the data);
  * bit-identical recovered state after reopening both stores from disk;
  * nothing left quarantined once the schedule drains.

A second, **network** phase runs the same idea one layer up: a sharded
fabric (loopback transports, so the ``rpc.send``/``rpc.recv`` seams fire
without sockets) ingests and queries under
``FaultPlan.random(seed, profile="network")`` — messages dropped,
duplicated, delayed, and reordered — and must end with every
acknowledged append present exactly once and every query count equal to
the clean single-node reference (zero acked-write loss, zero wrong
bits).

Artifacts land in ``results/chaos/``: the fault schedule + fired-event
report (``seed<N>.faults.json``, ``seed<N>.network.faults.json``) and
the end-of-run service health (``seed<N>.health.json``) — on a CI
failure these are what you read.

Usage: python benchmarks/chaos.py [seed ...]      (default: 11 23 47)
"""
from __future__ import annotations

import json
import os
import sys
import tempfile

import numpy as np

sys.path.insert(0, "src")

SEEDS = (11, 23, 47)
OUT_DIR = os.path.join("results", "chaos")
M, BLOCK, WORDS, N_BLOCKS = 12, 96, 3, 8
APPEND_RETRIES = 12


def _blocks(seed: int):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, M, (BLOCK, WORDS), dtype=np.int32)
            for _ in range(N_BLOCKS)]


def _run(root: str, plan):
    """One ingest+serve+maintenance workload; returns per-wave counts and
    the final service health dict."""
    from repro.db import BitmapDB
    from repro.engine.planner import key
    from repro.fault import FaultInjector

    db = BitmapDB(num_keys=M, path=root, spill_records=256)
    svc = db.serve(background=True, max_delay_ms=1.0, wave_retries=3,
                   breaker_cooldown_s=0.05, idle_after_ms=50.0)
    inj = FaultInjector(plan).install() if plan is not None else None
    try:
        waves = []
        for block in _blocks(7):
            for _ in range(APPEND_RETRIES):     # acked-or-retried ingest
                try:
                    db.append_encoded(block)
                    break
                except OSError:
                    continue
            else:
                raise RuntimeError("append never acknowledged")
            waves.append([svc.submit(key(i)).count for i in range(M)])
    finally:
        if inj is not None:
            inj.uninstall()
    if not svc._maint_ex.flush(30):
        raise RuntimeError("maintenance flush timed out")
    health = svc.health()
    svc.close()
    return waves, health, inj


def _reopened_counts(root: str):
    from repro.db.session import open_db
    from repro.engine.planner import key

    db = open_db(root, num_keys=M)
    try:
        return db.num_records, [db.query(key(i)).count for i in range(M)]
    finally:
        db.store.close()


def run_seed(seed: int) -> list[str]:
    """Returns a list of failure strings (empty = pass) and writes the
    artifacts for this seed."""
    from repro.fault import FaultPlan

    from repro.obs import export as obs_export
    from repro.obs import trace as obs_trace

    plan = FaultPlan.random(seed, profile="all")
    with tempfile.TemporaryDirectory() as tmp:
        clean_waves, _, _ = _run(os.path.join(tmp, "clean"), None)
        # trace the faulted run: every fired fault lands as a
        # zero-duration fault.<kind> event inside whatever span it
        # interrupted, so the merged JSONL artifact shows WHERE in the
        # serve/maintenance/store chain each injection hit
        tracer = obs_trace.Tracer(capacity=1 << 18)
        obs_trace.install(tracer)
        try:
            chaos_waves, health, inj = _run(os.path.join(tmp, "chaos"),
                                            plan)
        finally:
            obs_trace.uninstall(tracer)
        n_a, counts_a = _reopened_counts(os.path.join(tmp, "clean"))
        n_b, counts_b = _reopened_counts(os.path.join(tmp, "chaos"))

    obs_export.write_jsonl(
        tracer.spans(), os.path.join(OUT_DIR, f"seed{seed}.trace.jsonl"))
    with open(os.path.join(OUT_DIR, f"seed{seed}.faults.json"), "w") as f:
        f.write(inj.report_json())
    with open(os.path.join(OUT_DIR, f"seed{seed}.health.json"), "w") as f:
        json.dump(health, f, indent=2, sort_keys=True, default=repr)
        f.write("\n")

    failures = []
    if chaos_waves != clean_waves:
        failures.append("served bits differ from the clean run")
    if (n_a, counts_a) != (n_b, counts_b):
        failures.append(f"recovered state differs: {n_a} vs {n_b} records")
    if health["store"] and health["store"]["quarantined"]:
        failures.append(f"segments left quarantined: "
                        f"{health['store']['quarantined']}")
    return failures


def run_network_seed(seed: int) -> list[str]:
    """The fabric phase: sharded appends + queries under the network
    fault profile.  Every ``append_encoded`` that RETURNS is an
    acknowledged write — the pass condition is that all of them (and
    nothing else) are present at the end, with query counts identical
    to a clean single-node session over the same records."""
    from repro.db import BitmapDB
    from repro.engine.planner import key
    from repro.fabric.client import FabricClient
    from repro.fabric.shardmap import ShardMap
    from repro.fault import FaultInjector, FaultPlan

    plan = FaultPlan.random(seed, profile="network", n_faults=24,
                            max_occurrence=48, max_stall_s=0.002)
    blocks = _blocks(13)
    # clean single-node truth
    ref = BitmapDB(num_keys=M)
    for b in blocks:
        ref.append_encoded(b)
    truth = [ref.query(key(i)).count for i in range(M)]

    # schemaless session: every column shares the key range, so a key
    # predicate is NOT column-0-only — cardinality=0 disables pruning
    # (routing still hashes column 0) and every query fans out
    sm = ShardMap(num_shards=3, strategy="hash", column_index=0,
                  base=0, cardinality=0, seed=seed)
    fc = FabricClient.local(
        [BitmapDB(num_keys=M) for _ in range(3)], sm,
        max_delay_ms=1.0, request_timeout_s=0.5, request_retries=10,
        append_retries=12)
    failures = []
    acked = 0
    inj = FaultInjector(plan).install()
    try:
        for b in blocks:
            acked = fc.append_encoded(b)      # returns only when acked
            mid = [fc.submit(key(i)).count for i in range(M)]
            if any(c > t for c, t in zip(mid, truth)):
                failures.append("mid-run count exceeds the reference")
        final = [fc.submit(key(i)).count for i in range(M)]
        stored = sum(p["num_records"] for p in fc.info())
    finally:
        inj.uninstall()
        fc.close()

    with open(os.path.join(OUT_DIR,
                           f"seed{seed}.network.faults.json"), "w") as f:
        f.write(inj.report_json())
    if acked != len(blocks) * BLOCK:
        failures.append(f"acked {len(blocks) * BLOCK} records, fabric "
                        f"reports {acked}")
    if stored != len(blocks) * BLOCK:
        failures.append(f"shards hold {stored} records, {acked} were "
                        f"acknowledged (lost or double-applied write)")
    if final != truth:
        failures.append("fabric counts differ from the clean "
                        "single-node reference (acked write lost or "
                        "double-applied)")
    return failures


def main(*argv: str) -> int:
    seeds = tuple(int(a) for a in argv) or SEEDS
    os.makedirs(OUT_DIR, exist_ok=True)
    bad = 0
    for seed in seeds:
        failures = run_seed(seed)
        status = "FAIL" if failures else "ok"
        print(f"chaos seed={seed}: {status}"
              + "".join(f"\n  - {f}" for f in failures), flush=True)
        bad += bool(failures)
    for seed in seeds:
        failures = run_network_seed(seed)
        status = "FAIL" if failures else "ok"
        print(f"chaos seed={seed} profile=network: {status}"
              + "".join(f"\n  - {f}" for f in failures), flush=True)
        bad += bool(failures)
    print(f"chaos smoke: {len(seeds) - bad}/{len(seeds)} seeds clean "
          f"(artifacts in {OUT_DIR}/)")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(*sys.argv[1:]))
